//! `zann` — CLI for the compressed-id ANN system.
//!
//! Subcommands:
//!   bench-table1|bench-table2|bench-table3|bench-table4|bench-fig2|bench-fig3
//!                       — regenerate the paper's tables/figures
//!   bench-search-qps    — search throughput sweep over IVF *and* graph
//!                         backends (QPS + latency percentiles, writes
//!                         BENCH_search.json)
//!   bench-decode        — id-decode + scan-kernel throughput: per-codec
//!                         MB/s and ids/s across list sizes, blocked ADC
//!                         and fused coarse scalar vs dispatched SIMD
//!                         (writes BENCH_decode.json)
//!   bench-churn         — mutable-IVF churn: delete/insert throughput,
//!                         post-compaction bits/id vs a static build,
//!                         search parity (writes BENCH_churn.json)
//!   bench-recall        — recall@1/recall@10 vs exact groundtruth across
//!                         codec × backend × search knob, with QPS and
//!                         bits/id per point (writes BENCH_recall.json;
//!                         gated in CI against a committed baseline)
//!   bench-serve         — sharded serving node under mixed read/write
//!                         traffic with zipf-skewed tenants: per-tenant
//!                         QPS + latency percentiles, shed counts, shard
//!                         imbalance (writes BENCH_serve.json)
//!   build               — build an index (--backend
//!                         ivf|nsg|hnsw|dynamic|sharded) and save it to
//!                         the zann container (--out PATH)
//!   add                 — insert vectors into a saved dynamic index
//!   delete              — tombstone ids in a saved dynamic index
//!   compact             — merge + re-encode a saved dynamic index
//!   check-parity        — audit a dynamic index against a from-scratch
//!                         static build over the same live set
//!   info                — print the stats header of a saved index; for
//!                         a sharded container (or a directory of shard
//!                         containers) also one line per shard; --json
//!                         emits the same stats machine-readably
//!   serve               — reopen a saved index (zero transcode) and
//!                         serve a query batch through the coordinator,
//!                         verifying responses against direct search
//!   serve-demo          — build an index in memory and serve a batch
//!                         (PJRT coarse path if artifacts exist)
//!   inject-faults       — chaos gate: build every codec × backend
//!                         container, apply seeded corruptions, and
//!                         prove each one is detected (no panic, hang,
//!                         or silently wrong answer); exits non-zero
//!                         on any escape
//!   inject-crashes      — durability gate: kill-point matrix over WAL
//!                         ingest, checkpoints, shard swaps, atomic
//!                         commits, torn WAL tails and boundary-torn
//!                         containers (plus real kill -9 runs); every
//!                         injection must recover all acknowledged
//!                         writes bit-identically; exits non-zero on
//!                         any loss
//!   metrics             — run a small self-contained serving workload
//!                         and print the observability registry
//!                         (Prometheus text format, or JSON with --json)
//!   bench-obs           — self-measurement: the same serve workload
//!                         with stage-trace sampling off vs. on, and the
//!                         instrumentation overhead delta
//!                         (writes BENCH_obs.json)
//!   sizes               — bits/id summary for one dataset/index
//!
//! Common flags: --n --nq --dim --k --seed --threads --dataset
//! (sift|deep|ssnpp) --codec --runs --full (paper-scale N=1e6)

use std::path::Path;
use std::sync::Arc;
use zann::api::{persist, AnnIndex, AnnScratch, GraphIndex, IndexStats, QueryParams};
use zann::codecs::CodecSpec;
use zann::coordinator::{Coordinator, ServeConfig};
use zann::datasets::generate;
use zann::dynamic::{CompactionPolicy, DynamicBuildParams, DynamicIvf};
use zann::eval::experiments::{self, Scale};
use zann::eval::{bench_entries, fmt3, Table};
use zann::graph::hnsw::{Hnsw, HnswParams};
use zann::graph::nsg::{Nsg, NsgParams};
use zann::index::{IvfBuildParams, IvfIndex, SearchParams, VectorMode};
use zann::runtime::{default_artifact_dir, EngineHandle};
use zann::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "bench-table1" => bench_entries::table1(&args),
        "bench-table2" => bench_entries::table2(&args),
        "bench-table3" => bench_entries::table3(&args),
        "bench-table4" => bench_entries::table4(&args),
        "bench-fig2" => bench_entries::fig2(&args),
        "bench-fig3" => bench_entries::fig3(&args),
        "bench-search-qps" => bench_entries::search_qps(&args),
        "bench-decode" => bench_entries::decode(&args),
        "bench-churn" => bench_entries::churn(&args),
        "bench-recall" => bench_entries::recall(&args),
        "bench-serve" => bench_entries::serve(&args),
        "sizes" => sizes(&args),
        "build" => build_cmd(&args),
        "add" => add_cmd(&args),
        "delete" => delete_cmd(&args),
        "compact" => compact_cmd(&args),
        "check-parity" => check_parity_cmd(&args),
        "info" => info_cmd(&args),
        "serve" => serve_cmd(&args),
        "serve-demo" => serve_demo(&args),
        "inject-faults" => inject_faults_cmd(&args),
        "inject-crashes" => inject_crashes_cmd(&args),
        // Hidden helper: the crash harness's child-process ingest victim.
        "crash-victim" => crash_victim_cmd(&args),
        "metrics" => metrics_cmd(&args),
        "bench-obs" => bench_entries::obs(&args),
        _ => {
            eprintln!(
                "usage: zann <bench-table1|bench-table2|bench-table3|bench-table4|\n\
                 bench-fig2|bench-fig3|bench-search-qps|bench-decode|bench-churn|\n\
                 bench-recall|bench-serve|bench-obs|sizes|\n\
                 build --out PATH [--backend ivf|nsg|hnsw|dynamic|sharded]\n\
                 \u{20}\u{20}[--shards S] [--router hash|kmeans]|\n\
                 add PATH --add-n N|delete PATH --frac F|--ids A,B|compact PATH|\n\
                 check-parity PATH|info PATH_OR_DIR [--json]|\n\
                 serve PATH [--deadline-ms MS] [--queue-depth N] [--metrics-json PATH]\n\
                 \u{20}\u{20}[--metrics-prom PATH] [--trace-dump PATH]|\n\
                 serve-demo|metrics [--json] [--out PATH]|\n\
                 inject-faults [--seed S] [--mutations M] [--timeout-ms MS]|\n\
                 inject-crashes [--seed S] [--tail-stride T] [--min-injections N]\n\
                 \u{20}\u{20}[--victim-kills K] [--build-kills K]>\n\
                 [--n N] [--dataset sift|deep|ssnpp] [--codec NAME] ..."
            );
        }
    }
}

/// Parse `--codec` through the registry; on a typo, print the valid-name
/// list and exit instead of panicking deep inside an index build.
fn codec_or_exit(args: &Args, default: &str) -> String {
    let name = args.get_or("codec", default);
    match CodecSpec::parse(name) {
        Ok(spec) => spec.name().to_string(),
        Err(e) => {
            eprintln!("--codec: {e}");
            std::process::exit(2);
        }
    }
}

/// One parseable stats line shared by build/info/serve (ci.sh greps it).
/// Beyond the totals it carries the churn-visibility fields: live and
/// tombstoned-but-stored counts, write-buffer rows, segment count and
/// per-segment bits/id, so compression under live mutation is
/// observable from the CLI alone.
fn print_stats(s: &IndexStats, file_bytes: Option<u64>) {
    let mut line = format!(
        "zann-index kind={} codec={} n={} dim={} edges={} id_bits={} code_bits={} link_bits={} \
         bits_per_id={:.3} payload_bytes={} live={} deleted={} buffer_rows={} segments={} \
         aux_bits={} checksummed={}",
        s.kind.name(),
        s.codec,
        s.n,
        s.dim,
        s.edges,
        s.id_bits,
        s.code_bits,
        s.link_bits,
        s.bits_per_id(),
        s.payload_bytes(),
        s.live,
        s.deleted,
        s.buffer_rows,
        s.segments.len(),
        s.aux_bits,
        s.checksummed,
    );
    if !s.segments.is_empty() {
        let per: Vec<String> =
            s.segments.iter().map(|g| format!("{:.3}", g.bits_per_id())).collect();
        line.push_str(&format!(" seg_bpi={}", per.join(",")));
    }
    if let Some(b) = file_bytes {
        line.push_str(&format!(" file_bytes={b}"));
    }
    println!("{line}");
}

/// Machine-readable counterpart of `print_stats` (the `info --json`
/// path). Hand-rolled like the bench emitters; ci.sh round-trips the
/// output through a real JSON parser.
fn stats_json(s: &IndexStats, file_bytes: Option<u64>) -> String {
    let bits_per_link = if s.edges > 0 { s.link_bits as f64 / s.edges as f64 } else { 0.0 };
    let mut j = format!(
        "{{\"kind\": \"{}\", \"codec\": \"{}\", \"n\": {}, \"dim\": {}, \"edges\": {}, \
         \"id_bits\": {}, \"code_bits\": {}, \"link_bits\": {}, \"aux_bits\": {}, \
         \"bits_per_id\": {:.3}, \"bits_per_link\": {:.3}, \"payload_bytes\": {}, \
         \"live\": {}, \"deleted\": {}, \"buffer_rows\": {}, \"checksummed\": {}",
        s.kind.name(),
        zann::obs::expo::escape_json(&s.codec),
        s.n,
        s.dim,
        s.edges,
        s.id_bits,
        s.code_bits,
        s.link_bits,
        s.aux_bits,
        s.bits_per_id(),
        bits_per_link,
        s.payload_bytes(),
        s.live,
        s.deleted,
        s.buffer_rows,
        s.checksummed,
    );
    let per: Vec<String> = s.segments.iter().map(|g| format!("{:.3}", g.bits_per_id())).collect();
    j.push_str(&format!(
        ", \"segments\": {}, \"seg_bits_per_id\": [{}]",
        s.segments.len(),
        per.join(", ")
    ));
    if let Some(b) = file_bytes {
        j.push_str(&format!(", \"file_bytes\": {b}"));
    }
    j.push('}');
    j
}

/// Bits/id summary for one configuration.
fn sizes(args: &Args) {
    let scale = bench_entries::scale_from(args);
    let kind = bench_entries::datasets_from(args)[0];
    let k = args.usize("k", 1024);
    let rows = experiments::table1_ivf(&scale, kind, &[k], &experiments::T1_CODECS);
    let mut t = Table::new(&["index", "codec", "bits/id", "ratio vs unc64"]);
    for row in rows {
        for (codec, bpe) in &row.bpe {
            t.row(vec![format!("IVF{}", row.k), codec.clone(), fmt3(*bpe), fmt3(64.0 / bpe)]);
        }
    }
    println!("{}", t.render());
}

/// Build an index of any backend and persist it to the container format.
fn build_cmd(args: &Args) {
    let out = match args.get("out") {
        Some(p) => p.to_string(),
        None => {
            eprintln!("build: --out PATH is required");
            std::process::exit(2);
        }
    };
    let backend = args.get_or("backend", "ivf").to_string();
    let codec = codec_or_exit(args, "roc");
    let scale = bench_entries::scale_from(args);
    let kind = bench_entries::datasets_from(args)[0];
    println!("generating {} vectors ({}, dim {})...", scale.n, kind.name(), scale.dim);
    let ds = generate(kind, scale.n, 1, scale.dim, scale.seed);
    println!("building {backend} index ({codec} streams)...");
    let index: Box<dyn AnnIndex> = match backend.as_str() {
        "ivf" => {
            let m = args.usize("m", 8);
            let bits = args.usize("bits", 8) as u32;
            let vectors = match args.get_or("vectors", "flat") {
                "flat" => VectorMode::Flat,
                "pq" => VectorMode::Pq { m, bits },
                "pq-compressed" | "pqc" => VectorMode::PqCompressed { m, bits },
                other => {
                    eprintln!("build: unknown --vectors {other:?} (flat|pq|pq-compressed)");
                    std::process::exit(2);
                }
            };
            Box::new(IvfIndex::build(
                &ds.data,
                ds.dim,
                &IvfBuildParams {
                    k: args.usize("k", 1024.min((scale.n / 16).max(4))),
                    id_codec: codec.clone(),
                    vectors,
                    threads: scale.threads,
                    seed: scale.seed,
                    ..Default::default()
                },
            ))
        }
        "dynamic" => {
            let params = DynamicBuildParams {
                ivf: IvfBuildParams {
                    k: args.usize("k", 1024.min((scale.n / 16).max(4))),
                    id_codec: codec.clone(),
                    vectors: VectorMode::Flat,
                    threads: scale.threads,
                    seed: scale.seed,
                    ..Default::default()
                },
                policy: policy_from(args, CompactionPolicy::default()),
            };
            match DynamicIvf::build(&ds.data, ds.dim, &params) {
                Ok(idx) => Box::new(idx),
                Err(e) => {
                    eprintln!("build: {e}");
                    std::process::exit(2);
                }
            }
        }
        "nsg" => {
            let r = args.usize("r", 32);
            let nsg = Nsg::build(
                &ds.data,
                ds.dim,
                &NsgParams {
                    r,
                    knn_k: r.max(48),
                    threads: scale.threads,
                    seed: scale.seed,
                    ..Default::default()
                },
            );
            match GraphIndex::from_nsg(&nsg, &ds.data, &codec) {
                Ok(g) => Box::new(g),
                Err(e) => {
                    eprintln!("build: {e}");
                    std::process::exit(2);
                }
            }
        }
        "hnsw" => {
            let h = Hnsw::build(
                &ds.data,
                ds.dim,
                &HnswParams { m: args.usize("m", 16), ef_construction: 100, seed: scale.seed },
            );
            match GraphIndex::from_hnsw(&h, &ds.data, &codec) {
                Ok(g) => Box::new(g),
                Err(e) => {
                    eprintln!("build: {e}");
                    std::process::exit(2);
                }
            }
        }
        "sharded" => {
            let router = match zann::serve::RouterKind::parse(args.get_or("router", "hash")) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("build: {e}");
                    std::process::exit(2);
                }
            };
            let params = zann::serve::ShardedBuildParams {
                shards: args.usize("shards", 4),
                router,
                ivf: IvfBuildParams {
                    k: args.usize("k", 1024.min((scale.n / 16).max(4))),
                    id_codec: codec.clone(),
                    vectors: VectorMode::Flat,
                    threads: scale.threads,
                    seed: scale.seed,
                    ..Default::default()
                },
            };
            match zann::serve::ShardedIndex::build(&ds.data, ds.dim, &params) {
                Ok(idx) => Box::new(idx),
                Err(e) => {
                    eprintln!("build: {e}");
                    std::process::exit(2);
                }
            }
        }
        other => {
            eprintln!("build: unknown --backend {other:?} (ivf|nsg|hnsw|dynamic|sharded)");
            std::process::exit(2);
        }
    };
    let stats = index.stats();
    match index.save(Path::new(&out)) {
        Ok(bytes) => {
            print_stats(&stats, Some(bytes));
            println!(
                "saved {out}: {bytes} bytes for a {} byte payload ({} overhead)",
                stats.payload_bytes(),
                bytes.saturating_sub(stats.payload_bytes()),
            );
        }
        Err(e) => {
            eprintln!("build: save failed: {e}");
            std::process::exit(1);
        }
    }
}

/// Compaction knobs for the dynamic subcommands, overriding `base` only
/// where a flag was actually passed — `base` is the persisted policy
/// when reopening an index (so `add`/`delete`/`compact` respect the
/// knobs the index was built with) and the defaults for `build`.
fn policy_from(args: &Args, base: CompactionPolicy) -> CompactionPolicy {
    CompactionPolicy {
        flush_rows: args.usize("flush-rows", base.flush_rows),
        max_segments: args.usize("max-segments", base.max_segments),
        max_dead_frac: args.f64("max-dead-frac", base.max_dead_frac),
        auto: if args.has("no-auto-compact") {
            false
        } else if args.has("auto-compact") {
            true
        } else {
            base.auto
        },
    }
}

/// Reopen a dynamic container (the mutation subcommands need the
/// concrete mutable index, not a `dyn AnnIndex`).
fn open_dynamic_or_exit(args: &Args, cmd: &str) -> (String, DynamicIvf) {
    let path = match args.positional.get(1) {
        Some(p) => p.clone(),
        None => {
            eprintln!("usage: zann {cmd} PATH [flags]");
            std::process::exit(2);
        }
    };
    match persist::open_dynamic(Path::new(&path)) {
        Ok(mut idx) => {
            idx.set_policy(policy_from(args, idx.policy()));
            (path, idx)
        }
        Err(e) => {
            eprintln!("{cmd}: {e:?}");
            std::process::exit(1);
        }
    }
}

fn save_dynamic_or_exit(idx: &DynamicIvf, path: &str, cmd: &str) -> u64 {
    match idx.save(Path::new(path)) {
        Ok(bytes) => bytes,
        Err(e) => {
            eprintln!("{cmd}: save failed: {e}");
            std::process::exit(1);
        }
    }
}

/// Insert `--add-n` seeded random vectors into a saved dynamic index
/// and write it back (exercises the write buffer + auto flush path).
fn add_cmd(args: &Args) {
    let (path, mut idx) = open_dynamic_or_exit(args, "add");
    let n = args.usize("add-n", 1000);
    let dim = idx.dim();
    let mut rng = zann::util::Rng::new(args.u64("seed", 43));
    let rows: Vec<f32> = (0..n * dim).map(|_| rng.normal()).collect();
    let t0 = std::time::Instant::now();
    let range = match idx.add(&rows) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("add: {e}");
            std::process::exit(1);
        }
    };
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "added {n} vectors (ids {}..{}) in {:.3}s ({:.0}/s); {} segments + {} buffered rows",
        range.start,
        range.end,
        secs,
        n as f64 / secs.max(1e-12),
        idx.num_segments(),
        idx.buffer_rows(),
    );
    let bytes = save_dynamic_or_exit(&idx, &path, "add");
    print_stats(&AnnIndex::stats(&idx), Some(bytes));
}

/// Tombstone ids in a saved dynamic index: an explicit `--ids` list, or
/// `--frac` of the live set sampled with `--seed`.
fn delete_cmd(args: &Args) {
    let (path, mut idx) = open_dynamic_or_exit(args, "delete");
    let victims: Vec<u32> = if let Some(list) = args.get("ids") {
        list.split(',')
            .map(|v| {
                v.trim().parse().unwrap_or_else(|_| {
                    eprintln!("delete: bad --ids entry {v:?}");
                    std::process::exit(2);
                })
            })
            .collect()
    } else {
        let frac = args.f64("frac", 0.1);
        if !(0.0..=1.0).contains(&frac) {
            eprintln!("delete: --frac {frac} out of [0, 1]");
            std::process::exit(2);
        }
        let live = idx.live_ids();
        let target = ((live.len() as f64) * frac).round() as usize;
        let mut rng = zann::util::Rng::new(args.u64("seed", 44));
        rng.sample_distinct(live.len() as u64, target)
            .into_iter()
            .map(|i| live[i as usize])
            .collect()
    };
    let t0 = std::time::Instant::now();
    let mut deleted = 0usize;
    let mut missing = 0usize;
    for &id in &victims {
        match idx.delete(id) {
            Ok(true) => deleted += 1,
            Ok(false) => missing += 1,
            Err(e) => {
                eprintln!("delete: {e}");
                std::process::exit(1);
            }
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "deleted {deleted} ids ({missing} unknown/already dead) in {:.3}s ({:.0}/s); \
         {} tombstoned rows awaiting compaction",
        secs,
        deleted as f64 / secs.max(1e-12),
        idx.dead_stored(),
    );
    let bytes = save_dynamic_or_exit(&idx, &path, "delete");
    print_stats(&AnnIndex::stats(&idx), Some(bytes));
}

/// Fully compact a saved dynamic index and write it back.
fn compact_cmd(args: &Args) {
    let (path, mut idx) = open_dynamic_or_exit(args, "compact");
    let before = AnnIndex::stats(&idx);
    let t0 = std::time::Instant::now();
    if let Err(e) = idx.compact() {
        eprintln!("compact: {e}");
        std::process::exit(1);
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "compacted {} segments + {} buffered rows (dropping {} tombstoned) in {:.3}s: \
         bits/id {:.3} -> {:.3}",
        before.segments.len(),
        before.buffer_rows,
        before.deleted,
        secs,
        before.bits_per_id(),
        idx.bits_per_id(),
    );
    let bytes = save_dynamic_or_exit(&idx, &path, "compact");
    print_stats(&AnnIndex::stats(&idx), Some(bytes));
}

/// Audit a saved dynamic index against a from-scratch static build over
/// the same live set: every seeded random query must return identical
/// (distance, id) results, and the bits/id ratio is reported. Exits
/// non-zero on any divergence — the CI churn gate.
fn check_parity_cmd(args: &Args) {
    let (_, idx) = open_dynamic_or_exit(args, "check-parity");
    let nq = args.usize("nq", 256);
    let sp = SearchParams { nprobe: args.usize("nprobe", 16), k: args.usize("topk", 10) };
    let mut rng = zann::util::Rng::new(args.u64("seed", 42));
    let queries: Vec<f32> = (0..nq * idx.dim()).map(|_| rng.normal()).collect();
    let parity = match idx.check_parity(&queries, &sp) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("check-parity: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "parity: {}/{} queries identical to a from-scratch static build; \
         dynamic_bpi={:.3} static_bpi={:.3} ratio={:.4}",
        parity.identical,
        parity.queries,
        parity.dynamic_bits_per_id,
        parity.static_bits_per_id,
        parity.dynamic_bits_per_id / parity.static_bits_per_id.max(f64::MIN_POSITIVE),
    );
    if parity.identical != parity.queries {
        eprintln!(
            "check-parity: {} queries diverged from the static rebuild",
            parity.queries - parity.identical
        );
        std::process::exit(1);
    }
}

/// Print the stats of a saved index (reopens it, so the line reflects
/// what a server would actually load). A sharded container additionally
/// gets one per-shard line; a *directory* is treated as a set of shard
/// containers (every regular file, sorted by name) and reported the
/// same way with a synthesized aggregate.
fn info_cmd(args: &Args) {
    let path = match args.positional.get(1) {
        Some(p) => p.clone(),
        None => {
            eprintln!("usage: zann info PATH_OR_DIR [--json]");
            std::process::exit(2);
        }
    };
    let json = args.bool("json");
    if Path::new(&path).is_dir() {
        let dir = Path::new(&path);
        // A durable directory (MANIFEST present) is reported through its
        // manifest — WAL state included — never by opening every file.
        if zann::durable::manifest::is_durable_dir(dir) {
            return info_durable_dir(dir, json);
        }
        return info_dir(dir, json);
    }
    let file_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    let buf = match std::fs::read(&path) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("info: reading {path}: {e}");
            std::process::exit(1);
        }
    };
    let sharded = buf.len() > 6 && buf[6] == persist::KIND_SHARDED;
    if sharded {
        let idx = match persist::open_sharded_bytes(buf) {
            Ok(i) => i,
            Err(e) => {
                eprintln!("info: {e:?}");
                std::process::exit(1);
            }
        };
        if json {
            let shards: Vec<String> =
                idx.shard_stats().iter().map(|st| stats_json(st, None)).collect();
            println!(
                "{{\"router\": \"{}\", \"num_shards\": {}, \"aggregate\": {}, \"shards\": [{}]}}",
                idx.router().kind_name(),
                idx.num_shards(),
                stats_json(&AnnIndex::stats(&idx), Some(file_bytes)),
                shards.join(", "),
            );
            return;
        }
        print_stats(&AnnIndex::stats(&idx), Some(file_bytes));
        println!("router={} shards={}", idx.router().kind_name(), idx.num_shards());
        for (s, st) in idx.shard_stats().iter().enumerate() {
            print!("shard {s}: ");
            print_stats(st, None);
        }
        return;
    }
    let index = match persist::open_bytes(buf) {
        Ok(i) => i,
        Err(e) => {
            eprintln!("info: {e:?}");
            std::process::exit(1);
        }
    };
    if json {
        println!("{}", stats_json(&index.stats(), Some(file_bytes)));
    } else {
        print_stats(&index.stats(), Some(file_bytes));
    }
}

/// `zann info DIR`: every regular file in `DIR` (sorted by name) is
/// opened as one shard container; prints a synthesized aggregate line
/// followed by one line per shard (or one JSON object with `--json`).
fn info_dir(dir: &Path, json: bool) {
    let mut files: Vec<std::path::PathBuf> = match std::fs::read_dir(dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.is_file())
            .collect(),
        Err(e) => {
            eprintln!("info: reading directory {}: {e}", dir.display());
            std::process::exit(1);
        }
    };
    files.sort();
    if files.is_empty() {
        eprintln!("info: {} contains no shard containers", dir.display());
        std::process::exit(1);
    }
    let mut shards = Vec::new();
    let mut total_bytes = 0u64;
    for p in &files {
        total_bytes += std::fs::metadata(p).map(|m| m.len()).unwrap_or(0);
        match persist::open(p) {
            Ok(i) => shards.push((p.clone(), i.stats())),
            Err(e) => {
                eprintln!("info: {}: {e:?}", p.display());
                std::process::exit(1);
            }
        }
    }
    // Synthesized aggregate over the directory's shards, mirroring what
    // ShardedIndex::stats reports for a single multi-shard container.
    let codecs: Vec<&str> = {
        let mut c: Vec<&str> = shards.iter().map(|(_, s)| s.codec.as_str()).collect();
        c.sort();
        c.dedup();
        c
    };
    let agg = IndexStats {
        kind: zann::api::IndexKind::Sharded,
        n: shards.iter().map(|(_, s)| s.n).sum(),
        dim: shards[0].1.dim,
        edges: shards.iter().map(|(_, s)| s.edges).sum(),
        codec: codecs.join("+"),
        id_bits: shards.iter().map(|(_, s)| s.id_bits).sum(),
        code_bits: shards.iter().map(|(_, s)| s.code_bits).sum(),
        link_bits: shards.iter().map(|(_, s)| s.link_bits).sum(),
        live: shards.iter().map(|(_, s)| s.live).sum(),
        deleted: shards.iter().map(|(_, s)| s.deleted).sum(),
        buffer_rows: shards.iter().map(|(_, s)| s.buffer_rows).sum(),
        aux_bits: shards.iter().map(|(_, s)| s.aux_bits).sum(),
        checksummed: shards.iter().all(|(_, s)| s.checksummed),
        segments: shards
            .iter()
            .map(|(_, s)| zann::api::SegmentStats {
                rows: s.n,
                id_bits: s.id_bits,
                map_bits: 0,
            })
            .collect(),
    };
    if json {
        let per: Vec<String> = shards
            .iter()
            .map(|(p, st)| stats_json(st, std::fs::metadata(p).map(|m| m.len()).ok()))
            .collect();
        println!(
            "{{\"directory\": \"{}\", \"num_shards\": {}, \"aggregate\": {}, \"shards\": [{}]}}",
            zann::obs::expo::escape_json(&dir.display().to_string()),
            shards.len(),
            stats_json(&agg, Some(total_bytes)),
            per.join(", "),
        );
        return;
    }
    print_stats(&agg, Some(total_bytes));
    println!("directory {}: {} shard containers", dir.display(), shards.len());
    for (s, (p, st)) in shards.iter().enumerate() {
        print!("shard {s} ({}): ", p.file_name().unwrap_or_default().to_string_lossy());
        print_stats(st, std::fs::metadata(p).map(|m| m.len()).ok());
    }
}

/// Total size of the regular files in `dir` (best-effort, for the
/// `file_bytes` column of a durable-directory report).
fn dir_bytes(dir: &Path) -> u64 {
    std::fs::read_dir(dir)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter_map(|e| e.metadata().ok())
                .filter(|m| m.is_file())
                .map(|m| m.len())
                .sum()
        })
        .unwrap_or(0)
}

/// `zann info` on a durable directory: report strictly through the
/// manifest. A dynamic store additionally reports its WAL — size and the
/// pending (not yet checkpointed) records that a restart would replay —
/// without mutating anything on disk.
fn info_durable_dir(dir: &Path, json: bool) {
    use zann::durable::manifest::Manifest;
    use zann::durable::{node as durable_node, store, wal};
    let m = match Manifest::load(dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("info: {}: {e:?}", dir.display());
            std::process::exit(1);
        }
    };
    match m.get("kind") {
        Some(store::KIND_DYNAMIC_DIR) => {
            let (base, wal_file) = match (m.get("base"), m.get("wal")) {
                (Some(b), Some(w)) => (b, w),
                _ => {
                    eprintln!("info: {}: manifest missing base/wal entries", dir.display());
                    std::process::exit(1);
                }
            };
            let mut index = match persist::open_dynamic(&dir.join(base)) {
                Ok(i) => i,
                Err(e) => {
                    eprintln!("info: {}: {e:?}", dir.display());
                    std::process::exit(1);
                }
            };
            let replayed = match wal::replay(&dir.join(wal_file)) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("info: {}: {e:?}", dir.display());
                    std::process::exit(1);
                }
            };
            let (mut pending_rows, mut pending_deletes) = (0usize, 0usize);
            for rec in &replayed.records {
                if let Err(e) = store::apply(&mut index, rec) {
                    eprintln!("info: {}: {e:?}", dir.display());
                    std::process::exit(1);
                }
                match rec {
                    wal::WalRecord::Add { dim, rows, .. } => {
                        pending_rows += rows.len() / *dim as usize
                    }
                    wal::WalRecord::Delete { ids } => pending_deletes += ids.len(),
                }
            }
            let wal_bytes = replayed.valid_bytes + replayed.torn_bytes;
            if json {
                println!(
                    "{{\"durable\": {{\"kind\": \"dynamic\", \"generation\": {}, \
                     \"wal_bytes\": {}, \"pending_records\": {}, \"pending_rows\": {}, \
                     \"pending_deletes\": {}, \"torn_bytes\": {}}}, \"stats\": {}}}",
                    m.generation,
                    wal_bytes,
                    replayed.records.len(),
                    pending_rows,
                    pending_deletes,
                    replayed.torn_bytes,
                    stats_json(&AnnIndex::stats(&index), Some(dir_bytes(dir))),
                );
                return;
            }
            print_stats(&AnnIndex::stats(&index), Some(dir_bytes(dir)));
            println!(
                "durable kind=dynamic generation={} wal_bytes={} pending_records={} \
                 pending_rows={} pending_deletes={} torn_bytes={}",
                m.generation,
                wal_bytes,
                replayed.records.len(),
                pending_rows,
                pending_deletes,
                replayed.torn_bytes,
            );
        }
        Some(durable_node::KIND_NODE_DIR) => {
            let (idx, generation) = match durable_node::open_node_dir(dir) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("info: {}: {e:?}", dir.display());
                    std::process::exit(1);
                }
            };
            if json {
                let shards: Vec<String> =
                    idx.shard_stats().iter().map(|st| stats_json(st, None)).collect();
                println!(
                    "{{\"durable\": {{\"kind\": \"node\", \"generation\": {generation}}}, \
                     \"router\": \"{}\", \"num_shards\": {}, \"aggregate\": {}, \
                     \"shards\": [{}]}}",
                    idx.router().kind_name(),
                    idx.num_shards(),
                    stats_json(&AnnIndex::stats(&idx), Some(dir_bytes(dir))),
                    shards.join(", "),
                );
                return;
            }
            print_stats(&AnnIndex::stats(&idx), Some(dir_bytes(dir)));
            println!(
                "durable kind=node generation={generation} router={} shards={}",
                idx.router().kind_name(),
                idx.num_shards()
            );
            for (s, st) in idx.shard_stats().iter().enumerate() {
                print!("shard {s}: ");
                print_stats(st, None);
            }
        }
        other => {
            eprintln!("info: {}: unknown durable kind {:?}", dir.display(), other);
            std::process::exit(1);
        }
    }
}

/// Reopen a saved index and serve a seeded random query batch through
/// the coordinator, verifying every response against direct search.
fn serve_cmd(args: &Args) {
    let path = match args.positional.get(1) {
        Some(p) => p.clone(),
        None => {
            eprintln!(
                "usage: zann serve PATH [--nq N] [--nprobe P] [--ef E] [--topk K] \
                 [--deadline-ms MS] [--queue-depth N] [--dump-results FILE] \
                 [--metrics-json FILE] [--metrics-prom FILE] [--trace-dump FILE]"
            );
            std::process::exit(2);
        }
    };
    let index: Arc<dyn AnnIndex> = match persist::open(Path::new(&path)) {
        Ok(i) => Arc::from(i),
        Err(e) => {
            eprintln!("serve: {e:?}");
            std::process::exit(1);
        }
    };
    let file_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    print_stats(&index.stats(), Some(file_bytes));
    let engine = if index.coarse_info().is_some() {
        match EngineHandle::spawn(&default_artifact_dir()) {
            Ok(h) => {
                println!("engine up: {} PJRT executables", h.num_executables);
                Some(h)
            }
            Err(e) => {
                println!("engine unavailable ({e}); pure-rust coarse path");
                None
            }
        }
    } else {
        println!("graph backend: no coarse stage, direct scan path");
        None
    };
    let sp = QueryParams {
        k: args.usize("topk", 10),
        nprobe: args.usize("nprobe", 16),
        ef: args.usize("ef", 64),
    };
    let nq = args.usize("nq", 256);
    let dim = index.dim();
    let mut rng = zann::util::Rng::new(args.u64("seed", 42));
    let queries: Vec<Vec<f32>> =
        (0..nq).map(|_| (0..dim).map(|_| rng.normal()).collect()).collect();
    let deadline_ms = args.usize("deadline-ms", 0);
    let coord = Coordinator::start(
        index.clone(),
        engine,
        ServeConfig {
            batch_size: args.usize("batch", 64),
            search: sp.clone(),
            // The whole batch is enqueued before any reply is read, so
            // the default admission queue must cover it; an explicit
            // --queue-depth exercises the Overloaded path instead.
            queue_depth: args.usize("queue-depth", nq.max(1024)),
            deadline: (deadline_ms > 0)
                .then(|| std::time::Duration::from_millis(deadline_ms as u64)),
            ..Default::default()
        },
    );
    let t0 = std::time::Instant::now();
    let responses = coord.client.search_many(queries.clone()).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    // Every rust-path `Ok` response must match a direct search on the
    // reopened index — the end-to-end proof that open did not disturb
    // the stores. Degraded responses (Timeout/Overloaded/Failed) are
    // counted separately: they are structured refusals, not answers.
    // Batches scored by a PJRT executable are excluded from
    // the bit-exact check: only the pure-rust coarse kernel is
    // documented bit-identical to the direct path (XLA may differ in
    // the last ulp, legitimately reordering exact ties).
    let mut scratch = AnnScratch::default();
    let mut want = Vec::new();
    let mut ok = 0usize;
    let mut via_pjrt = 0usize;
    let mut degraded = 0usize;
    for (qi, resp) in responses.iter().enumerate() {
        if !resp.is_ok() {
            degraded += 1;
            continue;
        }
        if resp.via_pjrt {
            via_pjrt += 1;
            continue;
        }
        index.search_into(&queries[qi], &sp, &mut scratch, &mut want);
        if resp.results == want {
            ok += 1;
        }
    }
    // Machine-comparable result dump: one line per (query, rank) with
    // the distance's exact f32 bit pattern. ci.sh serves the same index
    // under ZANN_SIMD=scalar and under the default dispatch and `cmp`s
    // the two dumps — the end-to-end SIMD/scalar identity gate.
    if let Some(dump) = args.get("dump-results") {
        let mut s = String::new();
        for (qi, resp) in responses.iter().enumerate() {
            for (ri, &(d, id)) in resp.results.iter().enumerate() {
                s.push_str(&format!("{qi} {ri} {:08x} {id} {}\n", d.to_bits(), resp.via_pjrt));
            }
        }
        if let Err(e) = std::fs::write(dump, &s) {
            eprintln!("serve: failed to write --dump-results {dump}: {e}");
            std::process::exit(1);
        }
        println!("dumped {} result lines to {dump}", s.lines().count());
    }
    let checked = responses.len() - via_pjrt - degraded;
    let mut note = String::new();
    if via_pjrt > 0 {
        note.push_str(&format!(" ({via_pjrt} PJRT-scored responses skipped: not bit-comparable)"));
    }
    if degraded > 0 {
        note.push_str(&format!(" ({degraded} degraded responses: timeout/overload/failure)"));
    }
    println!("serve: verified {ok}/{checked} responses identical to direct search{note}");
    println!(
        "served {} queries in {:.3}s ({:.0} qps); {}",
        responses.len(),
        wall,
        responses.len() as f64 / wall,
        coord.metrics.summary()
    );
    // Machine-readable counters (including the queue-depth high-water
    // mark) for dashboards / CI assertions, written after the batch so
    // the numbers cover the whole run.
    if let Some(mpath) = args.get("metrics-json") {
        // Superset of the historical flat object: the coordinator's own
        // counters keep their keys, and the whole observability registry
        // rides along under "registry" when the obs feature is on.
        let mut json = coord.metrics.metrics_json();
        if zann::obs::enabled() {
            json.truncate(json.rfind('}').unwrap_or(json.len()));
            json.push_str(&format!(", \"registry\": {}}}", zann::obs::global().render_json()));
        }
        if let Err(e) = std::fs::write(mpath, &json) {
            eprintln!("serve: failed to write --metrics-json {mpath}: {e}");
            std::process::exit(1);
        }
        println!("wrote metrics to {mpath}");
    }
    // Prometheus text rendering of the global registry — everything the
    // run touched: per-codec decode counters, per-coordinator latency
    // histograms, stage timings, SIMD dispatch tiers.
    if let Some(ppath) = args.get("metrics-prom") {
        let text = zann::obs::global().render_prometheus();
        if let Err(e) = std::fs::write(ppath, &text) {
            eprintln!("serve: failed to write --metrics-prom {ppath}: {e}");
            std::process::exit(1);
        }
        println!("wrote {} exposition lines to {ppath}", text.lines().count());
    }
    coord.stop();
    // Sampled per-query stage timelines (enable with ZANN_TRACE_SAMPLE).
    // After stop(): the workers have joined, so every sampled query's
    // completed timeline is in the ring — the dump is the whole run.
    if let Some(tpath) = args.get("trace-dump") {
        let spans = zann::obs::trace::take_spans();
        if let Err(e) = std::fs::write(tpath, zann::obs::trace::spans_json(&spans)) {
            eprintln!("serve: failed to write --trace-dump {tpath}: {e}");
            std::process::exit(1);
        }
        println!("dumped {} sampled query spans to {tpath}", spans.len());
    }
    if ok != checked {
        eprintln!("serve: {} responses diverged from direct search", checked - ok);
        std::process::exit(1);
    }
}

/// End-to-end serving demo: index + coordinator + PJRT engine.
fn serve_demo(args: &Args) {
    let scale = bench_entries::scale_from(args);
    let kind = bench_entries::datasets_from(args)[0];
    let n = args.usize("n", 100_000);
    let nq = args.usize("nq", 1024);
    let _ = Scale::default();
    let codec = codec_or_exit(args, "roc");
    println!("generating {} vectors ({})...", n, kind.name());
    let ds = generate(kind, n, nq, scale.dim, scale.seed);
    println!("building IVF{} ({} ids)...", args.usize("k", 1024), codec);
    let idx = Arc::new(IvfIndex::build(
        &ds.data,
        ds.dim,
        &IvfBuildParams {
            k: args.usize("k", 1024),
            id_codec: codec,
            threads: scale.threads,
            seed: scale.seed,
            ..Default::default()
        },
    ));
    println!("id payload: {} bits/id", fmt3(idx.bits_per_id()));
    let engine = match EngineHandle::spawn(&default_artifact_dir()) {
        Ok(h) => {
            println!("engine up: {} PJRT executables", h.num_executables);
            Some(h)
        }
        Err(e) => {
            println!("engine unavailable ({e}); pure-rust coarse path");
            None
        }
    };
    let coord = Coordinator::start(
        idx,
        engine,
        ServeConfig {
            batch_size: 64,
            search: QueryParams { nprobe: args.usize("nprobe", 16), k: 10, ..Default::default() },
            ..Default::default()
        },
    );
    let queries: Vec<Vec<f32>> = (0..nq).map(|qi| ds.query(qi).to_vec()).collect();
    let t0 = std::time::Instant::now();
    let responses = coord.client.search_many(queries).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {} queries in {:.3}s ({:.0} qps); {}",
        responses.len(),
        wall,
        responses.len() as f64 / wall,
        coord.metrics.summary()
    );
    coord.stop();
}

/// Exercise a tiny self-contained serving workload, then print the
/// global observability registry — a smoke/debug view of the exposition
/// layer without needing a saved index. `--json` switches from the
/// Prometheus text format to the JSON rendering; `--out FILE` writes
/// instead of printing. Status chatter goes to stderr so stdout is pure
/// exposition.
fn metrics_cmd(args: &Args) {
    if !zann::obs::enabled() {
        eprintln!("metrics: built without the `obs` feature; registry will be empty");
    }
    let kind = bench_entries::datasets_from(args)[0];
    let n = args.usize("n", 4_096);
    let nq = args.usize("nq", 64);
    let dim = args.usize("dim", 32);
    let seed = args.u64("seed", 42);
    let codec = codec_or_exit(args, "roc");
    let ds = generate(kind, n, nq, dim, seed);
    let idx = Arc::new(IvfIndex::build(
        &ds.data,
        ds.dim,
        &IvfBuildParams { k: args.usize("k", 64), id_codec: codec, seed, ..Default::default() },
    ));
    let coord = Coordinator::start(
        idx,
        None,
        ServeConfig {
            batch_size: 16,
            search: QueryParams { nprobe: 4, k: 10, ..Default::default() },
            ..Default::default()
        },
    );
    let queries: Vec<Vec<f32>> = (0..nq).map(|qi| ds.query(qi).to_vec()).collect();
    let responses = coord.client.search_many(queries).unwrap();
    coord.stop();
    eprintln!("metrics: served {} queries to populate the registry", responses.len());
    let out = if args.bool("json") {
        zann::obs::global().render_json()
    } else {
        zann::obs::global().render_prometheus()
    };
    match args.get("out") {
        Some(path) => {
            if let Err(e) = std::fs::write(path, &out) {
                eprintln!("metrics: failed to write --out {path}: {e}");
                std::process::exit(1);
            }
            eprintln!("metrics: wrote {} bytes to {path}", out.len());
        }
        None => print!("{out}"),
    }
}

/// Chaos gate: seeded corruption sweep over every codec × backend
/// container. Exits non-zero if any mutant panics, hangs, or answers
/// wrongly without being detected.
fn inject_faults_cmd(args: &Args) {
    let cfg = zann::eval::faults::ChaosConfig {
        seed: args.u64("seed", 7),
        mutations_per_target: args.usize("mutations", 40),
        timeout: std::time::Duration::from_millis(args.u64("timeout-ms", 5000)),
    };
    println!(
        "inject-faults: seed={} mutations/target={} timeout={}ms",
        cfg.seed,
        cfg.mutations_per_target,
        cfg.timeout.as_millis()
    );
    let report = match zann::eval::faults::run_chaos_sweep(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("inject-faults: sweep could not run: {e:?}");
            std::process::exit(1);
        }
    };
    println!("{}", report.summary());
    if !report.passed() {
        for f in &report.failures {
            eprintln!("inject-faults: ESCAPE {f}");
        }
        std::process::exit(1);
    }
}

fn inject_crashes_cmd(args: &Args) {
    let cfg = zann::eval::crashes::CrashConfig {
        seed: args.u64("seed", 7),
        // Kill -9 children are `zann crash-victim` / `zann build` runs of
        // this very binary.
        exe: std::env::current_exe().ok(),
        victim_kills: args.usize("victim-kills", 24),
        build_kills: args.usize("build-kills", 8),
        tail_stride: args.usize("tail-stride", 1),
        min_injections: args.usize("min-injections", 200),
    };
    println!(
        "inject-crashes: seed={} tail_stride={} victim_kills={} build_kills={} \
         min_injections={}",
        cfg.seed, cfg.tail_stride, cfg.victim_kills, cfg.build_kills, cfg.min_injections
    );
    let report = match zann::eval::crashes::run_crash_sweep(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("inject-crashes: sweep could not run: {e:?}");
            std::process::exit(1);
        }
    };
    println!("{}", report.summary());
    if !report.passed() {
        for f in &report.failures {
            eprintln!("inject-crashes: FAILURE {f}");
        }
        std::process::exit(1);
    }
}

/// Hidden helper for the crash harness: open (or seed) a durable dynamic
/// directory and ingest seeded batches until killed, printing `ack
/// <batch> <start> <end>` only after the WAL fsync acknowledged the
/// batch. The harness kill -9s this process at a random point and
/// verifies that recovery retains every acked line.
fn crash_victim_cmd(args: &Args) {
    use std::io::Write as _;
    let dir = match args.positional.get(1) {
        Some(d) => std::path::PathBuf::from(d),
        None => {
            eprintln!(
                "usage: zann crash-victim DIR [--seed S] [--rows R] [--batches B] \
                 [--checkpoint-every C] [--dim D]"
            );
            std::process::exit(2);
        }
    };
    let seed = args.u64("seed", 7);
    let rows = args.usize("rows", 8);
    let batches = args.usize("batches", 512);
    let every = args.usize("checkpoint-every", 16);
    if !zann::durable::manifest::is_durable_dir(&dir) {
        // Fresh directory: seed generation 0 with a small built base so
        // ci.sh can drive the WAL path without a separate init command.
        let dim = args.usize("dim", 8);
        let ds = generate(zann::datasets::Kind::DeepLike, 64, 1, dim, seed);
        let base = DynamicIvf::build(
            &ds.data,
            dim,
            &DynamicBuildParams {
                ivf: IvfBuildParams {
                    k: 4,
                    id_codec: "roc".into(),
                    threads: 2,
                    ..Default::default()
                },
                policy: CompactionPolicy { flush_rows: 64, auto: false, ..Default::default() },
            },
        );
        let base = match base {
            Ok(b) => b,
            Err(e) => {
                eprintln!("crash-victim: seeding base index: {e:?}");
                std::process::exit(1);
            }
        };
        if let Err(e) = zann::durable::store::DurableDynamic::create(&dir, base) {
            eprintln!("crash-victim: creating {}: {e:?}", dir.display());
            std::process::exit(1);
        }
    }
    let (mut store, _) = match zann::durable::store::DurableDynamic::open(&dir) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("crash-victim: {e:?}");
            std::process::exit(1);
        }
    };
    let dim = store.index().dim();
    for b in 0..batches {
        let data = zann::eval::crashes::victim_rows(seed, b, rows, dim);
        let r = match store.add(&data) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("crash-victim: add: {e:?}");
                std::process::exit(1);
            }
        };
        // Stdout is block-buffered into the harness's pipe: flush so the
        // ack is observable strictly after the fsync, never before.
        println!("ack {b} {} {}", r.start, r.end);
        let _ = std::io::stdout().flush();
        if every > 0 && (b + 1) % every == 0 {
            if let Err(e) = store.checkpoint() {
                eprintln!("crash-victim: checkpoint: {e:?}");
                std::process::exit(1);
            }
        }
    }
}
