//! ANN indexes. [`ivf`] implements the inverted-file index whose id lists
//! are the primary compression target of the paper (Fig. 1 top).

pub mod ivf;

pub use ivf::{IvfBuildParams, IvfIndex, SearchParams, SearchScratch, VectorMode};
