//! ANN indexes whose auxiliary id payloads are the paper's compression
//! target (Fig. 1 top).
//!
//! [`ivf`] implements the inverted-file index: vectors are reordered into
//! cluster-major order (Faiss layout), so each cluster must store the
//! original vector ids explicitly — that per-cluster id list is what the
//! per-list codecs (`unc64`/`compact`/`ef`/`roc`) compress, and what the
//! wavelet-tree store (`wt`/`wt1`) replaces entirely with one
//! random-access structure over the assignment sequence.
//!
//! Two orthogonal build axes, both chosen in [`ivf::IvfBuildParams`]:
//!
//! * **id storage** (`id_codec`) — how `[cluster → ids]` is represented;
//!   lossless, so search results are identical across codecs (the reason
//!   the paper does not report recall per codec);
//! * **vector storage** ([`ivf::VectorMode`]) — raw f32 rows, PQ codes
//!   scanned via ADC, or per-cluster entropy-coded PQ codes (Fig. 3).
//!
//! Search follows the paper's deferred-id trick (§4.1): the top-k heap
//! collects packed `(cluster, offset)` payloads and only the final k
//! winners are resolved to real ids through `decode_nth`/`select`; codecs
//! without random access (ROC) instead decode each probed list during the
//! scan — the online-setting cost Table 2 measures.
//!
//! Graph-based indexes (NSG, HNSW) live in [`crate::graph`]; the serving
//! wrapper that batches queries over an [`ivf::IvfIndex`] lives in
//! [`crate::coordinator`].

pub mod ivf;

pub use ivf::{IvfBuildParams, IvfIndex, SearchParams, SearchScratch, VectorMode};
