//! IVF (inverted file) index with pluggable id compression.
//!
//! Layout follows Faiss: vectors are *reordered* into cluster-major order,
//! which is exactly why each cluster's original vector ids must be stored
//! explicitly — the green boxes of the paper's Fig. 1.  The id payload is
//! stored through one of:
//!
//! * a per-list [`IdCodec`] (`unc64`, `compact`, `ef`, `roc`) — the online
//!   setting (§4.2): one bit stream per cluster;
//! * a [`WaveletTree`] over the assignment sequence (`wt`, `wt1`) — full
//!   random access (§4.1): no per-cluster lists at all, ids are recovered
//!   with `select(cluster, offset)`.
//!
//! Search implements the paper's deferred-id trick: the top-k structure
//! collects packed `(cluster, offset)` pairs; only the final k winners are
//! resolved to real ids (via `decode_nth`/`select` for random-access
//! stores).  ROC has no random access, so each probed cluster's stream is
//! decoded during the scan — the id-decode cost that Table 2 measures.

use crate::codecs::wavelet::WaveletTree;
use crate::codecs::{pcodes, CodecSpec, DecodeScratch, IdCodec};
use crate::obs::trace::{self, Stage};
use crate::quant::coarse;
use crate::quant::kmeans::{self, KmeansConfig};
use crate::quant::pq::Pq;
use crate::quant::{l2_sq, TopK};
use crate::util::bytes::{Blobs, BlobsBuilder};
use crate::util::pool::default_threads;
use crate::util::{ReadBuf, WriteBuf};
use anyhow::{bail, ensure, Context, Result};

/// How vectors themselves are stored (orthogonal to id compression).
#[derive(Clone, Debug, PartialEq)]
pub enum VectorMode {
    /// Raw f32 vectors ("Flat quantizer" rows of Table 1/2).
    Flat,
    /// PQ codes scanned via ADC (PQ rows of Table 2 / Fig. 2).
    Pq { m: usize, bits: u32 },
    /// PQ codes entropy-coded per cluster with the eq. (6-7) model
    /// (Fig. 3); decoded per probed cluster at search time.
    PqCompressed { m: usize, bits: u32 },
}

pub struct IvfBuildParams {
    pub k: usize,
    pub train_iters: usize,
    pub seed: u64,
    pub threads: usize,
    /// One of: unc64 | unc32 | compact | ef | roc | wt | wt1.
    pub id_codec: String,
    pub vectors: VectorMode,
}

impl Default for IvfBuildParams {
    fn default() -> Self {
        IvfBuildParams {
            k: 1024,
            train_iters: 8,
            seed: 0x1df,
            threads: default_threads(),
            id_codec: "roc".into(),
            vectors: VectorMode::Flat,
        }
    }
}

pub struct SearchParams {
    pub nprobe: usize,
    pub k: usize,
}

impl Default for SearchParams {
    fn default() -> Self {
        SearchParams { nprobe: 16, k: 10 }
    }
}

enum IdStore {
    PerList {
        codec: Box<dyn IdCodec>,
        /// One compressed stream per cluster, end-to-end in one shared
        /// buffer — written verbatim by `save` and reopened zero-copy.
        blobs: Blobs,
        bits: u64,
        random_access: bool,
    },
    Wavelet {
        wt: WaveletTree,
    },
}

enum CodeStore {
    Flat(Vec<f32>),
    Pq {
        pq: Pq,
        codes: Vec<u16>,
    },
    PqCompressed {
        pq: Pq,
        /// Built once at index construction, shared by every probe (the
        /// decoder is stateless; per-decode state lives in the scratch).
        codec: pcodes::ClusterCodeCodec,
        /// `k × m` column streams, cluster-major (`c * m + j`), in one
        /// shared buffer — persisted verbatim like the id blobs.
        columns: Blobs,
        bits: u64,
    },
}

/// Reusable per-thread search scratch.
///
/// Everything a query needs beyond the index itself lives here — coarse
/// distances, probe ordering, the PQ LUT, decoded ids/codes, the top-k
/// heap and the per-cluster decoder state — so a warmed scratch makes
/// steady-state `IvfIndex::search_into` calls allocation-free for
/// random-access id stores, and allocation-free beyond first-touch
/// scratch growth for the per-cluster decoders (ROC, PqCompressed).
#[derive(Default)]
pub struct SearchScratch {
    pub(crate) coarse: Vec<f32>,
    pub(crate) probe_order: Vec<u32>,
    pub(crate) lut: Vec<f32>,
    pub(crate) ids: Vec<u32>,
    pub(crate) codes: Vec<u16>,
    /// Per-list ADC distances from the blocked SIMD scan (one entry per
    /// scanned row, reused across lists).
    pub(crate) dists: Vec<f32>,
    /// Batch-translated external ids of one segment list (dynamic index).
    pub(crate) exts: Vec<u32>,
    /// Surviving positions after batched tombstone filtering (dynamic).
    pub(crate) keep: Vec<u32>,
    pub(crate) topk: TopK,
    pub(crate) winners: Vec<(f32, u64)>,
    pub(crate) decode: DecodeScratch,
    /// Cached registry handles for the decode-path counters (kept on the
    /// scratch so the steady state never touches the registry lock).
    pub(crate) obs: DecodeObs,
}

/// Registry handles for the IVF decode-path instrumentation, cached per
/// scratch and re-resolved only when the codec label changes (a scratch
/// normally serves one index, so never).
#[derive(Default)]
pub(crate) struct DecodeObs {
    codec: String,
    handles: Option<DecodeHandles>,
    simd: Option<std::sync::Arc<crate::obs::Counter>>,
}

struct DecodeHandles {
    lists: std::sync::Arc<crate::obs::Counter>,
    ids: std::sync::Arc<crate::obs::Counter>,
    bits: std::sync::Arc<crate::obs::Counter>,
    reuse: std::sync::Arc<crate::obs::Counter>,
    grow: std::sync::Arc<crate::obs::Counter>,
}

impl DecodeObs {
    fn handles(&mut self, codec: &str) -> &DecodeHandles {
        if self.handles.is_none() || self.codec != codec {
            self.codec.clear();
            self.codec.push_str(codec);
            let l = [("codec", codec)];
            self.handles = Some(DecodeHandles {
                lists: crate::obs::counter("zann_lists_probed_total", &l),
                ids: crate::obs::counter("zann_ids_decoded_total", &l),
                bits: crate::obs::counter("zann_id_bits_decoded_total", &l),
                reuse: crate::obs::counter("zann_scratch_reuse_total", &l),
                grow: crate::obs::counter("zann_scratch_grow_total", &l),
            });
        }
        self.handles.as_ref().unwrap()
    }

    fn simd(&mut self) -> &crate::obs::Counter {
        if self.simd.is_none() {
            self.simd = Some(crate::obs::counter(
                "zann_simd_dispatch_total",
                &[("level", crate::simd::level().name())],
            ));
        }
        self.simd.as_deref().unwrap()
    }

    /// Flush one query's worth of decode-path observations.
    pub(crate) fn record_query(
        &mut self,
        codec: &str,
        lists: u64,
        ids: u64,
        bits: u64,
        scratch_grew: bool,
    ) {
        if !crate::obs::enabled() {
            return;
        }
        let h = self.handles(codec);
        h.lists.add(lists);
        h.ids.add(ids);
        h.bits.add(bits);
        if scratch_grew {
            h.grow.inc();
        } else {
            h.reuse.inc();
        }
        self.simd().inc();
    }
}

pub struct IvfIndex {
    pub dim: usize,
    pub n: usize,
    pub k: usize,
    pub centroids: Vec<f32>,
    /// `‖c‖²` per centroid, precomputed for the fused coarse kernel.
    pub centroid_norms: Vec<f32>,
    /// Cluster boundaries in the reordered arrays (k+1 entries).
    offsets: Vec<usize>,
    ids: IdStore,
    store: CodeStore,
    /// Canonical id-codec spec (distinguishes wt from wt1; persisted in
    /// the container header so `open` reconstructs the exact codec).
    spec: CodecSpec,
    /// False only when opened from a legacy v1 container (no per-section
    /// CRCs on disk); surfaced through `IndexStats::checksummed`.
    checksummed: bool,
}

impl IvfIndex {
    /// Build from row-major `data` (`n × dim`).
    pub fn build(data: &[f32], dim: usize, params: &IvfBuildParams) -> IvfIndex {
        let _n = data.len() / dim;
        let cfg = KmeansConfig {
            k: params.k,
            iters: params.train_iters,
            seed: params.seed,
            threads: params.threads,
            ..Default::default()
        };
        let centroids = kmeans::train(data, dim, &cfg);
        let k = centroids.len() / dim;
        let assign = kmeans::assign(data, dim, &centroids, params.threads);
        Self::build_preassigned(data, dim, &centroids, &assign, params, k)
    }

    /// Build with an existing coarse quantizer + assignment (used by the
    /// large-scale Table-4 bench to share one expensive clustering).
    pub fn build_preassigned(
        data: &[f32],
        dim: usize,
        centroids: &[f32],
        assign: &[u32],
        params: &IvfBuildParams,
        k: usize,
    ) -> IvfIndex {
        let n = data.len() / dim;
        // Bucket ids per cluster.
        let mut lists: Vec<Vec<u32>> = vec![Vec::new(); k];
        for (i, &c) in assign.iter().enumerate() {
            lists[c as usize].push(i as u32);
        }
        let mut offsets = Vec::with_capacity(k + 1);
        let mut acc = 0usize;
        for l in &lists {
            offsets.push(acc);
            acc += l.len();
        }
        offsets.push(acc);

        // Id payload FIRST: the codec's decode order becomes the canonical
        // within-cluster order (the paper's reordering invariance — ROC
        // decodes a permutation of the set, and vectors must follow it so
        // that scan offset o maps to the o-th decoded id).
        let universe = n as u32;
        let spec = CodecSpec::parse(&params.id_codec).unwrap_or_else(|e| panic!("{e}"));
        let (ids, lists) = match spec {
            CodecSpec::Wavelet(storage) => {
                // select(c, o) walks occurrences in id order = `lists` order.
                (IdStore::Wavelet { wt: WaveletTree::new(assign, k as u32, storage) }, lists)
            }
            _ => {
                let codec = spec.id_codec().unwrap_or_else(|e| panic!("{e}"));
                let mut bits = 0u64;
                let mut blobs = BlobsBuilder::new();
                let mut decoded = Vec::with_capacity(k);
                for l in &lists {
                    let enc = codec.encode(l, universe);
                    bits += enc.bits;
                    let mut order = Vec::with_capacity(l.len());
                    codec.decode(&enc.bytes, universe, l.len(), &mut order);
                    blobs.push(&enc.bytes);
                    decoded.push(order);
                }
                let random_access = codec.supports_random_access();
                (
                    IdStore::PerList { codec, blobs: blobs.finish(), bits, random_access },
                    decoded,
                )
            }
        };

        // Vector payload, cluster-major, in decode order.
        let store = match params.vectors {
            VectorMode::Flat => {
                let mut reordered = Vec::with_capacity(n * dim);
                for l in &lists {
                    for &id in l {
                        reordered.extend_from_slice(&data[id as usize * dim..(id as usize + 1) * dim]);
                    }
                }
                CodeStore::Flat(reordered)
            }
            VectorMode::Pq { m, bits } | VectorMode::PqCompressed { m, bits } => {
                let pq = Pq::train(data, dim, m, bits, params.seed ^ 0x99, params.threads);
                let codes = pq.encode_batch(data, params.threads);
                let mut reordered = Vec::with_capacity(n * m);
                for l in &lists {
                    for &id in l {
                        reordered.extend_from_slice(&codes[id as usize * m..(id as usize + 1) * m]);
                    }
                }
                if matches!(params.vectors, VectorMode::Pq { .. }) {
                    CodeStore::Pq { pq, codes: reordered }
                } else {
                    let codec = pcodes::ClusterCodeCodec::new(1 << bits, m);
                    let mut bits_total = 0u64;
                    let mut columns = BlobsBuilder::new();
                    for c in 0..k {
                        let rows = offsets[c + 1] - offsets[c];
                        let enc =
                            codec.encode(&reordered[offsets[c] * m..offsets[c + 1] * m], rows);
                        bits_total += enc.bits;
                        for col in &enc.columns {
                            columns.push(col);
                        }
                    }
                    CodeStore::PqCompressed { pq, codec, columns: columns.finish(), bits: bits_total }
                }
            }
        };

        let centroid_norms = coarse::centroid_norms(centroids, dim);
        IvfIndex {
            dim,
            n,
            k,
            centroids: centroids.to_vec(),
            centroid_norms,
            offsets,
            ids,
            store,
            spec,
            checksummed: true,
        }
    }

    pub fn list_len(&self, c: usize) -> usize {
        self.offsets[c + 1] - self.offsets[c]
    }

    /// Exact id payload size in bits (the Table-1 numerator).
    pub fn id_bits(&self) -> u64 {
        match &self.ids {
            IdStore::PerList { bits, .. } => *bits,
            IdStore::Wavelet { wt } => wt.size_bits() as u64,
        }
    }

    /// Bits per id — the Table-1 metric.
    pub fn bits_per_id(&self) -> f64 {
        self.id_bits() as f64 / self.n as f64
    }

    /// Vector payload size in bits (Fig. 3 numerator for PqCompressed).
    pub fn code_bits(&self) -> u64 {
        match &self.store {
            CodeStore::Flat(v) => v.len() as u64 * 32,
            CodeStore::Pq { pq, codes } => {
                (codes.len() / pq.m) as u64 * pq.code_bits() as u64
            }
            CodeStore::PqCompressed { bits, .. } => *bits,
        }
    }

    /// Search with coarse distances computed internally (pure rust).
    pub fn search(
        &self,
        query: &[f32],
        p: &SearchParams,
        scratch: &mut SearchScratch,
    ) -> Vec<(f32, u32)> {
        let mut out = Vec::with_capacity(p.k);
        self.search_into(query, p, scratch, &mut out);
        out
    }

    /// Like [`IvfIndex::search`], writing the results into a caller-owned
    /// buffer (replacing its contents). With a warmed `scratch` and a
    /// reused `out`, steady-state calls are the allocation-free hot path.
    pub fn search_into(
        &self,
        query: &[f32],
        p: &SearchParams,
        scratch: &mut SearchScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        scratch.coarse.clear();
        scratch.coarse.resize(self.k, 0.0);
        coarse::dists_into(
            query,
            &self.centroids,
            self.dim,
            &self.centroid_norms,
            &mut scratch.coarse,
        );
        self.search_with_coarse_inner(query, p, scratch, out);
    }

    /// Search with externally supplied coarse distances (the coordinator
    /// feeds PJRT-computed batches through this).
    pub fn search_with_coarse(
        &self,
        query: &[f32],
        coarse: &[f32],
        p: &SearchParams,
        scratch: &mut SearchScratch,
    ) -> Vec<(f32, u32)> {
        let mut out = Vec::with_capacity(p.k);
        self.search_with_coarse_into(query, coarse, p, scratch, &mut out);
        out
    }

    /// Buffer-reusing variant of [`IvfIndex::search_with_coarse`].
    pub fn search_with_coarse_into(
        &self,
        query: &[f32],
        coarse: &[f32],
        p: &SearchParams,
        scratch: &mut SearchScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        assert_eq!(coarse.len(), self.k);
        scratch.coarse.clear();
        scratch.coarse.extend_from_slice(coarse);
        self.search_with_coarse_inner(query, p, scratch, out);
    }

    fn search_with_coarse_inner(
        &self,
        query: &[f32],
        p: &SearchParams,
        scratch: &mut SearchScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        let nprobe = p.nprobe.min(self.k);
        let SearchScratch {
            coarse, probe_order, lut, ids, codes, dists, topk, winners, decode, obs, ..
        } = scratch;
        // Decode-path observations, accumulated locally and flushed once
        // per query (one handle-cache hit, five relaxed adds).
        let cap_before = ids.capacity() + codes.capacity() + dists.capacity();
        let (mut obs_lists, mut obs_ids, mut obs_bits) = (0u64, 0u64, 0u64);
        // Select the nprobe nearest centroids, then order that prefix
        // best-first: visiting the closest cluster first tightens the
        // top-k threshold early, so later clusters prune more rows.
        probe_order.clear();
        probe_order.extend(0..self.k as u32);
        if nprobe > 0 && nprobe < self.k {
            probe_order.select_nth_unstable_by(nprobe - 1, |&a, &b| {
                coarse[a as usize].total_cmp(&coarse[b as usize])
            });
        }
        let probes = &mut probe_order[..nprobe];
        probes.sort_unstable_by(|&a, &b| coarse[a as usize].total_cmp(&coarse[b as usize]));

        topk.reset(p.k);
        // Prepare the per-query LUT once for PQ stores — hoisted out of
        // the per-list probe loop (each probed cluster reuses the same
        // table) and written into the preshaped scratch slice.
        if let CodeStore::Pq { pq, .. } | CodeStore::PqCompressed { pq, .. } = &self.store {
            pq.lut(query, lut);
        }

        let defer_ids = match &self.ids {
            IdStore::PerList { random_access, .. } => *random_access,
            IdStore::Wavelet { .. } => true,
        };

        for &c in probes.iter() {
            let c = c as usize;
            let (start, end) = (self.offsets[c], self.offsets[c + 1]);
            if start == end {
                continue;
            }
            obs_lists += 1;
            // For non-random-access codecs (ROC) the whole list is decoded
            // now — the online-setting cost the paper measures — through
            // the reusable decode scratch.
            if !defer_ids {
                if let IdStore::PerList { codec, blobs, .. } = &self.ids {
                    let _span = trace::span(Stage::ListDecode);
                    ids.clear();
                    codec.decode_into(blobs.get(c), self.n as u32, end - start, ids, decode);
                    obs_ids += (end - start) as u64;
                    obs_bits += blobs.get(c).len() as u64 * 8;
                }
            }
            match &self.store {
                CodeStore::Flat(v) => {
                    let _span = trace::span(Stage::AdcScan);
                    for (o, row) in v[start * self.dim..end * self.dim]
                        .chunks_exact(self.dim)
                        .enumerate()
                    {
                        let d = l2_sq(query, row);
                        if d < topk.threshold() {
                            topk.push(d, payload(c, o, defer_ids, ids));
                        }
                    }
                }
                CodeStore::Pq { pq, codes: stored } => {
                    // Two-phase blocked scan: the SIMD kernel fills one
                    // distance per row (bit-identical to per-row adc),
                    // then a dense pass feeds the top-k.
                    let _span = trace::span(Stage::AdcScan);
                    pq.adc_scan_into(lut, &stored[start * pq.m..end * pq.m], dists);
                    for (o, &d) in dists.iter().enumerate() {
                        if d < topk.threshold() {
                            topk.push(d, payload(c, o, defer_ids, ids));
                        }
                    }
                }
                CodeStore::PqCompressed { pq, codec, columns, .. } => {
                    let m = pq.m;
                    {
                        let _span = trace::span(Stage::ListDecode);
                        codec.decode_columns_into(
                            (0..m).map(|j| columns.get(c * m + j)),
                            end - start,
                            codes,
                            decode,
                        );
                    }
                    let _span = trace::span(Stage::AdcScan);
                    pq.adc_scan_into(lut, codes, dists);
                    for (o, &d) in dists.iter().enumerate() {
                        if d < topk.threshold() {
                            topk.push(d, payload(c, o, defer_ids, ids));
                        }
                    }
                }
            }
        }

        // Resolve payloads to ids.
        let merge_span = trace::span(Stage::TopkMerge);
        topk.drain_sorted_into(winners);
        out.clear();
        out.reserve(winners.len());
        for &(d, pl) in winners.iter() {
            if defer_ids {
                let c = (pl >> 32) as usize;
                let o = (pl & 0xffff_ffff) as usize;
                out.push((d, self.resolve_id(c, o)));
            } else {
                out.push((d, pl as u32));
            }
        }
        drop(merge_span);
        if defer_ids {
            // Random-access stores decode exactly the winners.
            obs_ids += winners.len() as u64;
        }
        if crate::obs::enabled() {
            let cap_after = ids.capacity() + codes.capacity() + dists.capacity();
            obs.record_query(
                self.spec.name(),
                obs_lists,
                obs_ids,
                obs_bits,
                cap_after > cap_before,
            );
        }
    }

    /// Resolve (cluster, offset) → id via the random-access store
    /// (allocation-free for unc64/unc32/compact/ef).
    fn resolve_id(&self, c: usize, o: usize) -> u32 {
        match &self.ids {
            IdStore::PerList { codec, blobs, .. } => codec
                .decode_nth(blobs.get(c), self.n as u32, self.list_len(c), o)
                .expect("offset out of range"),
            IdStore::Wavelet { wt } => wt.select(c as u32, o as u64).expect("wt select") as u32,
        }
    }

    /// Decode the full id list of cluster `c` into a reused buffer
    /// through a reusable [`DecodeScratch`] — the allocation-free bulk
    /// path for audits, migrations and the codec table benches.
    pub fn decode_list_into(&self, c: usize, out: &mut Vec<u32>, scratch: &mut DecodeScratch) {
        let n = self.list_len(c);
        out.clear();
        match &self.ids {
            IdStore::PerList { codec, blobs, .. } => {
                codec.decode_into(blobs.get(c), self.n as u32, n, out, scratch);
            }
            IdStore::Wavelet { wt } => {
                out.extend((0..n).map(|o| wt.select(c as u32, o as u64).unwrap() as u32));
            }
        }
    }

    /// Decode the full id list of cluster `c` (allocating convenience
    /// wrapper over [`IvfIndex::decode_list_into`]).
    pub fn decode_list(&self, c: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.list_len(c));
        self.decode_list_into(c, &mut out, &mut DecodeScratch::default());
        out
    }

    /// Canonical id-store spec name (bench labels, persisted header).
    pub fn id_codec_name(&self) -> &str {
        self.spec.name()
    }

    /// Whether the index came from a checksummed (v2) container or was
    /// built in-process; false only for legacy v1 opens.
    pub(crate) fn checksummed(&self) -> bool {
        self.checksummed
    }

    /// Decode every per-list id stream (and, for PqCompressed stores,
    /// every cluster's code columns) once through the fallible paths —
    /// the corruption check applied when a legacy (unchecksummed)
    /// container is opened, so bad bytes surface as an open-time error
    /// instead of a panic mid-query.
    fn validate_decode(&self) -> Result<()> {
        let mut scratch = DecodeScratch::default();
        if let IdStore::PerList { codec, blobs, .. } = &self.ids {
            let mut out = Vec::new();
            for c in 0..self.k {
                out.clear();
                codec
                    .try_decode_into(blobs.get(c), self.n as u32, self.list_len(c), &mut out, &mut scratch)
                    .with_context(|| format!("id list of cluster {c} failed to decode"))?;
            }
        }
        if let CodeStore::PqCompressed { pq, codec, columns, .. } = &self.store {
            let m = pq.m;
            let mut codes = Vec::new();
            for c in 0..self.k {
                codec
                    .try_decode_columns_into(
                        (0..m).map(|j| columns.get(c * m + j)),
                        self.list_len(c),
                        &mut codes,
                        &mut scratch,
                    )
                    .with_context(|| format!("code columns of cluster {c} failed to decode"))?;
            }
        }
        Ok(())
    }
}

/// The raw building blocks of a Flat, per-list-codec IVF index —
/// consumed by `dynamic::DynamicIvf::from_static`, which adopts the
/// compressed id streams and reordered rows verbatim as its first
/// immutable segment.
pub(crate) struct IvfParts {
    pub dim: usize,
    pub n: usize,
    pub k: usize,
    pub centroids: Vec<f32>,
    pub centroid_norms: Vec<f32>,
    pub offsets: Vec<usize>,
    pub blobs: Blobs,
    pub id_bits: u64,
    pub spec: CodecSpec,
    /// Cluster-major rows in codec decode order.
    pub vectors: Vec<f32>,
}

impl IvfIndex {
    /// Decompose into [`IvfParts`] without touching the compressed
    /// streams. Only Flat per-list indexes qualify (the combinations a
    /// dynamic index can absorb today); anything else is an actionable
    /// error.
    pub(crate) fn into_parts(self) -> Result<IvfParts> {
        let (blobs, id_bits) = match self.ids {
            IdStore::PerList { blobs, bits, .. } => (blobs, bits),
            IdStore::Wavelet { .. } => bail!(
                "dynamic indexes need a per-list id codec ({}), not a wavelet store",
                crate::codecs::PER_LIST_CODECS.join("|")
            ),
        };
        let vectors = match self.store {
            CodeStore::Flat(v) => v,
            CodeStore::Pq { .. } | CodeStore::PqCompressed { .. } => {
                bail!("dynamic indexes currently store Flat vectors, not PQ codes")
            }
        };
        Ok(IvfParts {
            dim: self.dim,
            n: self.n,
            k: self.k,
            centroids: self.centroids,
            centroid_norms: self.centroid_norms,
            offsets: self.offsets,
            blobs,
            id_bits,
            spec: self.spec,
            vectors,
        })
    }
}

/// Container persistence: the compressed id/code streams are written
/// verbatim (no re-encode) and reopened as slices into the file buffer
/// (no transcode). See `api::persist` for the framing.
impl IvfIndex {
    /// Serialize to the zann container format (`api::persist`).
    ///
    /// Only per-list id stores persist; the wavelet variants would need
    /// bitmap serialization and are rejected with an actionable error.
    pub(crate) fn to_container_bytes(&self) -> Result<Vec<u8>> {
        use crate::api::persist;
        let (blobs, id_bits) = match &self.ids {
            IdStore::PerList { blobs, bits, .. } => (blobs, *bits),
            IdStore::Wavelet { .. } => bail!(
                "persistence for wavelet id stores (wt/wt1) is not implemented; \
                 build with a per-list codec ({})",
                crate::codecs::PER_LIST_CODECS.join("|")
            ),
        };

        let mut head = WriteBuf::new();
        head.put_u64(self.dim as u64);
        head.put_u64(self.n as u64);
        head.put_u64(self.k as u64);
        head.put_str(self.spec.name());
        let (mode, m, pq_bits) = match &self.store {
            CodeStore::Flat(_) => (0u8, 0u64, 0u32),
            CodeStore::Pq { pq, .. } => (1, pq.m as u64, pq.bits),
            CodeStore::PqCompressed { pq, .. } => (2, pq.m as u64, pq.bits),
        };
        head.put_u8(mode);
        head.put_u64(m);
        head.put_u32(pq_bits);
        head.put_u64(id_bits);
        head.put_u64(self.code_bits());

        let mut file = persist::file_header(persist::KIND_IVF);
        persist::push_section(&mut file, b"HEAD", &head.bytes);
        let mut cent = WriteBuf::new();
        cent.put_f32s(&self.centroids);
        persist::push_section(&mut file, b"CENT", &cent.bytes);
        let mut offs = WriteBuf::new();
        offs.put_u64s(&self.offsets.iter().map(|&o| o as u64).collect::<Vec<u64>>());
        persist::push_section(&mut file, b"OFFS", &offs.bytes);
        let mut idof = WriteBuf::new();
        idof.put_u64s(blobs.offsets());
        persist::push_section(&mut file, b"IDOF", &idof.bytes);
        persist::push_section(&mut file, b"IDBL", blobs.payload());

        match &self.store {
            CodeStore::Flat(v) => {
                let mut w = WriteBuf::new();
                w.put_f32s(v);
                persist::push_section(&mut file, b"VECS", &w.bytes);
            }
            CodeStore::Pq { pq, codes } => {
                let mut w = WriteBuf::new();
                pq.serialize(&mut w);
                persist::push_section(&mut file, b"PQBK", &w.bytes);
                persist::push_section(&mut file, b"PQCD", &persist::pack_codes(codes, pq.bits));
            }
            CodeStore::PqCompressed { pq, columns, .. } => {
                let mut w = WriteBuf::new();
                pq.serialize(&mut w);
                persist::push_section(&mut file, b"PQBK", &w.bytes);
                let mut pcof = WriteBuf::new();
                pcof.put_u64s(columns.offsets());
                persist::push_section(&mut file, b"PCOF", &pcof.bytes);
                persist::push_section(&mut file, b"PCBL", columns.payload());
            }
        }
        persist::finish_container(&mut file);
        Ok(file)
    }

    /// Rebuild from a parsed container. Id (and compressed-code) sections
    /// become [`Blobs`] over the borrowed file buffer — no payload is
    /// copied or re-coded; only derived structures (centroid norms) are
    /// recomputed.
    pub(crate) fn from_container(c: &crate::api::persist::Container) -> Result<IvfIndex> {
        let head = c.section(b"HEAD")?;
        let mut r = ReadBuf::new(head.as_slice());
        let dim = r.get_u64()? as usize;
        let n = r.get_u64()? as usize;
        let k = r.get_u64()? as usize;
        let codec_name = r.get_str()?;
        let mode = r.get_u8()?;
        let m = r.get_u64()? as usize;
        let pq_bits = r.get_u32()?;
        let id_bits = r.get_u64()?;
        let code_bits = r.get_u64()?;
        ensure!(dim >= 1 && k >= 1, "degenerate header (dim={dim}, k={k})");
        let spec = CodecSpec::parse(&codec_name).context("index header names its id codec")?;

        let sec = c.section(b"CENT")?;
        let centroids = ReadBuf::new(sec.as_slice()).get_f32s()?;
        ensure!(
            centroids.len() == k * dim,
            "centroid section holds {} floats for k={k}, dim={dim}",
            centroids.len()
        );
        let sec = c.section(b"OFFS")?;
        let offsets_u64 = ReadBuf::new(sec.as_slice()).get_u64s()?;
        ensure!(offsets_u64.len() == k + 1, "expected {} cluster offsets", k + 1);
        ensure!(
            offsets_u64[0] == 0
                && offsets_u64.windows(2).all(|w| w[0] <= w[1])
                && *offsets_u64.last().unwrap() as usize == n,
            "cluster offsets are not a monotone partition of [0, {n})"
        );
        let offsets: Vec<usize> = offsets_u64.iter().map(|&o| o as usize).collect();

        let sec = c.section(b"IDOF")?;
        let idof = ReadBuf::new(sec.as_slice()).get_u64s()?;
        let blobs = Blobs::from_parts(c.section(b"IDBL")?, idof)?;
        ensure!(blobs.count() == k, "id store holds {} blobs for k={k}", blobs.count());
        let codec = spec.id_codec().context("reopening the per-list id store")?;
        let random_access = codec.supports_random_access();
        let ids = IdStore::PerList { codec, blobs, bits: id_bits, random_access };

        let store = match mode {
            0 => {
                let sec = c.section(b"VECS")?;
                let v = ReadBuf::new(sec.as_slice()).get_f32s()?;
                ensure!(v.len() == n * dim, "vector section holds {} floats", v.len());
                CodeStore::Flat(v)
            }
            1 | 2 => {
                ensure!((1..=16).contains(&pq_bits), "bad PQ bit width {pq_bits}");
                let sec = c.section(b"PQBK")?;
                let pq = Pq::deserialize(&mut ReadBuf::new(sec.as_slice()))?;
                ensure!(
                    pq.m == m && pq.bits == pq_bits && pq.dim() == dim,
                    "PQ codebook shape disagrees with the header"
                );
                if mode == 1 {
                    let sec = c.section(b"PQCD")?;
                    let codes =
                        crate::api::persist::unpack_codes(sec.as_slice(), pq_bits, n * m)?;
                    CodeStore::Pq { pq, codes }
                } else {
                    let sec = c.section(b"PCOF")?;
                    let pcof = ReadBuf::new(sec.as_slice()).get_u64s()?;
                    let columns = Blobs::from_parts(c.section(b"PCBL")?, pcof)?;
                    ensure!(
                        columns.count() == k * m,
                        "code store holds {} column blobs for k={k}, m={m}",
                        columns.count()
                    );
                    let codec = pcodes::ClusterCodeCodec::new(1 << pq_bits, m);
                    CodeStore::PqCompressed { pq, codec, columns, bits: code_bits }
                }
            }
            other => bail!("unknown vector-mode tag {other}"),
        };

        let centroid_norms = coarse::centroid_norms(&centroids, dim);
        let idx = IvfIndex {
            dim,
            n,
            k,
            centroids,
            centroid_norms,
            offsets,
            ids,
            store,
            spec,
            checksummed: c.checksummed(),
        };
        if !c.checksummed() {
            // Legacy v1 file: no per-section CRC protected the payload
            // streams, so decode everything once now.
            idx.validate_decode().context("v1 IVF container failed decode validation")?;
        }
        Ok(idx)
    }
}

/// Heap payload: packed (cluster, offset) when ids resolve after search
/// (§4.1's deferred resolution), or the already-decoded id otherwise.
#[inline]
fn payload(c: usize, o: usize, defer: bool, decoded: &[u32]) -> u64 {
    if defer {
        ((c as u64) << 32) | o as u64
    } else {
        decoded[o] as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{generate, groundtruth, Kind};

    fn build_ds() -> crate::datasets::Dataset {
        generate(Kind::DeepLike, 4000, 50, 16, 11)
    }

    fn check_search_quality(codec: &str, vectors: VectorMode, min_recall: f64) {
        let ds = build_ds();
        let params = IvfBuildParams {
            k: 64,
            id_codec: codec.into(),
            vectors,
            threads: 2,
            ..Default::default()
        };
        let idx = IvfIndex::build(&ds.data, ds.dim, &params);
        let gt = groundtruth::exact_knn(&ds.data, &ds.queries, ds.dim, 10, 2);
        let sp = SearchParams { nprobe: 16, k: 10 };
        let mut scratch = SearchScratch::default();
        let results: Vec<Vec<u32>> = (0..ds.nq)
            .map(|qi| idx.search(ds.query(qi), &sp, &mut scratch).into_iter().map(|(_, id)| id).collect())
            .collect();
        let recall = groundtruth::nn_recall_at_k(&gt, 10, &results, 10);
        assert!(recall >= min_recall, "{codec} {:?}: recall={recall}", idx.id_codec_name());
    }

    #[test]
    fn all_id_codecs_same_results() {
        // Lossless id compression ⇒ identical search results across codecs
        // (the paper's reason for not reporting recall).
        let ds = build_ds();
        let sp = SearchParams { nprobe: 8, k: 10 };
        let mut baseline: Option<Vec<Vec<(f32, u32)>>> = None;
        for codec in ["unc64", "unc32", "compact", "ef", "roc", "wt", "wt1"] {
            let params = IvfBuildParams {
                k: 32,
                id_codec: codec.into(),
                threads: 2,
                ..Default::default()
            };
            let idx = IvfIndex::build(&ds.data, ds.dim, &params);
            let mut scratch = SearchScratch::default();
            let res: Vec<Vec<(f32, u32)>> =
                (0..20).map(|qi| idx.search(ds.query(qi), &sp, &mut scratch)).collect();
            match &baseline {
                None => baseline = Some(res),
                Some(b) => {
                    for (qi, (got, want)) in res.iter().zip(b).enumerate() {
                        let gd: Vec<u32> = got.iter().map(|r| r.1).collect();
                        let wd: Vec<u32> = want.iter().map(|r| r.1).collect();
                        assert_eq!(gd, wd, "codec={codec} query={qi}");
                    }
                }
            }
        }
    }

    #[test]
    fn flat_search_recall() {
        check_search_quality("roc", VectorMode::Flat, 0.85);
    }

    #[test]
    fn pq_search_recall() {
        check_search_quality("ef", VectorMode::Pq { m: 4, bits: 8 }, 0.5);
    }

    #[test]
    fn pq_compressed_matches_pq_results() {
        // Lossless code compression ⇒ identical distances to plain PQ.
        let ds = build_ds();
        let mk = |vectors| {
            IvfIndex::build(
                &ds.data,
                ds.dim,
                &IvfBuildParams {
                    k: 32,
                    id_codec: "compact".into(),
                    vectors,
                    threads: 2,
                    ..Default::default()
                },
            )
        };
        let a = mk(VectorMode::Pq { m: 4, bits: 8 });
        let b = mk(VectorMode::PqCompressed { m: 4, bits: 8 });
        let sp = SearchParams { nprobe: 8, k: 5 };
        let mut s1 = SearchScratch::default();
        let mut s2 = SearchScratch::default();
        for qi in 0..20 {
            let ra = a.search(ds.query(qi), &sp, &mut s1);
            let rb = b.search(ds.query(qi), &sp, &mut s2);
            assert_eq!(ra, rb, "query {qi}");
        }
        // And the compressed codes are no larger than plain ones (+streams
        // overhead is amortized at this size).
        assert!(b.code_bits() <= a.code_bits() + a.k as u64 * 64 * 4);
    }

    #[test]
    fn shared_scratch_across_queries_and_indexes_matches_fresh() {
        // One SearchScratch (and the DecodeScratch inside it) reused
        // across many queries and three indexes — different universes
        // (full vs half dataset) and different stores (flat ROC, flat EF,
        // compressed PQ codes) — must return exactly what a fresh scratch
        // returns for every query.
        let ds = build_ds();
        let sp = SearchParams { nprobe: 8, k: 10 };
        let mk = |data: &[f32], codec: &str, vectors: VectorMode| {
            IvfIndex::build(
                data,
                ds.dim,
                &IvfBuildParams {
                    k: 32,
                    id_codec: codec.into(),
                    vectors,
                    threads: 2,
                    ..Default::default()
                },
            )
        };
        let half = &ds.data[..2000 * ds.dim];
        let indexes = [
            mk(&ds.data, "roc", VectorMode::Flat),
            mk(half, "roc", VectorMode::Flat),
            mk(&ds.data, "ef", VectorMode::PqCompressed { m: 4, bits: 8 }),
        ];
        let mut shared = SearchScratch::default();
        let mut out = Vec::new();
        for qi in 0..30 {
            for (ii, idx) in indexes.iter().enumerate() {
                let mut fresh = SearchScratch::default();
                let want = idx.search(ds.query(qi), &sp, &mut fresh);
                idx.search_into(ds.query(qi), &sp, &mut shared, &mut out);
                assert_eq!(out, want, "query {qi} index {ii}");
            }
        }
    }

    #[test]
    fn decoded_lists_form_partition() {
        let ds = build_ds();
        // One reused buffer + decode scratch across every cluster and
        // codec: decode_list_into must agree with the allocating wrapper.
        let mut out = Vec::new();
        let mut scratch = DecodeScratch::default();
        for codec in ["roc", "ef", "wt1"] {
            let idx = IvfIndex::build(
                &ds.data,
                ds.dim,
                &IvfBuildParams { k: 16, id_codec: codec.into(), threads: 2, ..Default::default() },
            );
            let mut seen = vec![false; ds.n];
            for c in 0..idx.k {
                idx.decode_list_into(c, &mut out, &mut scratch);
                assert_eq!(out, idx.decode_list(c), "cluster {c} ({codec})");
                for &id in &out {
                    assert!(!seen[id as usize], "id {id} duplicated ({codec})");
                    seen[id as usize] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "missing ids ({codec})");
        }
    }

    #[test]
    fn bits_per_id_ordering() {
        // roc < ef < compact < unc64 on a reasonable IVF.
        let ds = build_ds();
        let bpe = |codec: &str| {
            IvfIndex::build(
                &ds.data,
                ds.dim,
                &IvfBuildParams { k: 16, id_codec: codec.into(), threads: 2, ..Default::default() },
            )
            .bits_per_id()
        };
        let (roc, ef, comp, unc) = (bpe("roc"), bpe("ef"), bpe("compact"), bpe("unc64"));
        assert!(roc < ef, "roc={roc} ef={ef}");
        assert!(ef < comp, "ef={ef} comp={comp}");
        assert!(comp < unc, "comp={comp} unc={unc}");
        assert_eq!(unc, 64.0);
    }
}
