//! IVF (inverted file) index with pluggable id compression.
//!
//! Layout follows Faiss: vectors are *reordered* into cluster-major order,
//! which is exactly why each cluster's original vector ids must be stored
//! explicitly — the green boxes of the paper's Fig. 1.  The id payload is
//! stored through one of:
//!
//! * a per-list [`IdCodec`] (`unc64`, `compact`, `ef`, `roc`) — the online
//!   setting (§4.2): one bit stream per cluster;
//! * a [`WaveletTree`] over the assignment sequence (`wt`, `wt1`) — full
//!   random access (§4.1): no per-cluster lists at all, ids are recovered
//!   with `select(cluster, offset)`.
//!
//! Search implements the paper's deferred-id trick: the top-k structure
//! collects packed `(cluster, offset)` pairs; only the final k winners are
//! resolved to real ids (via `decode_nth`/`select` for random-access
//! stores).  ROC has no random access, so each probed cluster's stream is
//! decoded during the scan — the id-decode cost that Table 2 measures.

use crate::codecs::wavelet::{WaveletTree, WtStorage};
use crate::codecs::{codec_by_name, pcodes, IdCodec};
use crate::quant::kmeans::{self, KmeansConfig};
use crate::quant::pq::Pq;
use crate::quant::{l2_sq, TopK};
use crate::util::pool::default_threads;

/// How vectors themselves are stored (orthogonal to id compression).
#[derive(Clone, Debug, PartialEq)]
pub enum VectorMode {
    /// Raw f32 vectors ("Flat quantizer" rows of Table 1/2).
    Flat,
    /// PQ codes scanned via ADC (PQ rows of Table 2 / Fig. 2).
    Pq { m: usize, bits: u32 },
    /// PQ codes entropy-coded per cluster with the eq. (6-7) model
    /// (Fig. 3); decoded per probed cluster at search time.
    PqCompressed { m: usize, bits: u32 },
}

pub struct IvfBuildParams {
    pub k: usize,
    pub train_iters: usize,
    pub seed: u64,
    pub threads: usize,
    /// One of: unc64 | unc32 | compact | ef | roc | wt | wt1.
    pub id_codec: String,
    pub vectors: VectorMode,
}

impl Default for IvfBuildParams {
    fn default() -> Self {
        IvfBuildParams {
            k: 1024,
            train_iters: 8,
            seed: 0x1df,
            threads: default_threads(),
            id_codec: "roc".into(),
            vectors: VectorMode::Flat,
        }
    }
}

pub struct SearchParams {
    pub nprobe: usize,
    pub k: usize,
}

impl Default for SearchParams {
    fn default() -> Self {
        SearchParams { nprobe: 16, k: 10 }
    }
}

enum IdStore {
    PerList {
        codec: Box<dyn IdCodec>,
        blobs: Vec<Vec<u8>>,
        bits: u64,
        random_access: bool,
    },
    Wavelet {
        wt: WaveletTree,
    },
}

enum CodeStore {
    Flat(Vec<f32>),
    Pq {
        pq: Pq,
        codes: Vec<u16>,
    },
    PqCompressed {
        pq: Pq,
        clusters: Vec<pcodes::EncodedCluster>,
        bits: u64,
    },
}

/// Reusable per-thread search scratch (no allocation on the hot path).
#[derive(Default)]
pub struct SearchScratch {
    coarse: Vec<f32>,
    probe_order: Vec<u32>,
    lut: Vec<f32>,
    ids: Vec<u32>,
    codes: Vec<u16>,
}

pub struct IvfIndex {
    pub dim: usize,
    pub n: usize,
    pub k: usize,
    pub centroids: Vec<f32>,
    /// Cluster boundaries in the reordered arrays (k+1 entries).
    offsets: Vec<usize>,
    ids: IdStore,
    store: CodeStore,
}

impl IvfIndex {
    /// Build from row-major `data` (`n × dim`).
    pub fn build(data: &[f32], dim: usize, params: &IvfBuildParams) -> IvfIndex {
        let _n = data.len() / dim;
        let cfg = KmeansConfig {
            k: params.k,
            iters: params.train_iters,
            seed: params.seed,
            threads: params.threads,
            ..Default::default()
        };
        let centroids = kmeans::train(data, dim, &cfg);
        let k = centroids.len() / dim;
        let assign = kmeans::assign(data, dim, &centroids, params.threads);
        Self::build_preassigned(data, dim, &centroids, &assign, params, k)
    }

    /// Build with an existing coarse quantizer + assignment (used by the
    /// large-scale Table-4 bench to share one expensive clustering).
    pub fn build_preassigned(
        data: &[f32],
        dim: usize,
        centroids: &[f32],
        assign: &[u32],
        params: &IvfBuildParams,
        k: usize,
    ) -> IvfIndex {
        let n = data.len() / dim;
        // Bucket ids per cluster.
        let mut lists: Vec<Vec<u32>> = vec![Vec::new(); k];
        for (i, &c) in assign.iter().enumerate() {
            lists[c as usize].push(i as u32);
        }
        let mut offsets = Vec::with_capacity(k + 1);
        let mut acc = 0usize;
        for l in &lists {
            offsets.push(acc);
            acc += l.len();
        }
        offsets.push(acc);

        // Id payload FIRST: the codec's decode order becomes the canonical
        // within-cluster order (the paper's reordering invariance — ROC
        // decodes a permutation of the set, and vectors must follow it so
        // that scan offset o maps to the o-th decoded id).
        let universe = n as u32;
        let (ids, lists) = match params.id_codec.as_str() {
            "wt" | "wt1" => {
                let storage = if params.id_codec == "wt" { WtStorage::Flat } else { WtStorage::Rrr };
                // select(c, o) walks occurrences in id order = `lists` order.
                (IdStore::Wavelet { wt: WaveletTree::new(assign, k as u32, storage) }, lists)
            }
            name => {
                let codec =
                    codec_by_name(name).unwrap_or_else(|| panic!("unknown id codec {name}"));
                let mut bits = 0u64;
                let mut blobs = Vec::with_capacity(k);
                let mut decoded = Vec::with_capacity(k);
                for l in &lists {
                    let enc = codec.encode(l, universe);
                    bits += enc.bits;
                    let mut order = Vec::with_capacity(l.len());
                    codec.decode(&enc.bytes, universe, l.len(), &mut order);
                    blobs.push(enc.bytes);
                    decoded.push(order);
                }
                let random_access = codec.supports_random_access();
                (IdStore::PerList { codec, blobs, bits, random_access }, decoded)
            }
        };

        // Vector payload, cluster-major, in decode order.
        let store = match params.vectors {
            VectorMode::Flat => {
                let mut reordered = Vec::with_capacity(n * dim);
                for l in &lists {
                    for &id in l {
                        reordered.extend_from_slice(&data[id as usize * dim..(id as usize + 1) * dim]);
                    }
                }
                CodeStore::Flat(reordered)
            }
            VectorMode::Pq { m, bits } | VectorMode::PqCompressed { m, bits } => {
                let pq = Pq::train(data, dim, m, bits, params.seed ^ 0x99, params.threads);
                let codes = pq.encode_batch(data, params.threads);
                let mut reordered = Vec::with_capacity(n * m);
                for l in &lists {
                    for &id in l {
                        reordered.extend_from_slice(&codes[id as usize * m..(id as usize + 1) * m]);
                    }
                }
                if matches!(params.vectors, VectorMode::Pq { .. }) {
                    CodeStore::Pq { pq, codes: reordered }
                } else {
                    let codec = pcodes::ClusterCodeCodec::new(1 << bits, m);
                    let mut bits_total = 0u64;
                    let clusters: Vec<pcodes::EncodedCluster> = (0..k)
                        .map(|c| {
                            let rows = offsets[c + 1] - offsets[c];
                            let enc = codec.encode(
                                &reordered[offsets[c] * m..offsets[c + 1] * m],
                                rows,
                            );
                            bits_total += enc.bits;
                            enc
                        })
                        .collect();
                    CodeStore::PqCompressed { pq, clusters, bits: bits_total }
                }
            }
        };

        IvfIndex { dim, n, k, centroids: centroids.to_vec(), offsets, ids, store }
    }

    pub fn list_len(&self, c: usize) -> usize {
        self.offsets[c + 1] - self.offsets[c]
    }

    /// Exact id payload size in bits (the Table-1 numerator).
    pub fn id_bits(&self) -> u64 {
        match &self.ids {
            IdStore::PerList { bits, .. } => *bits,
            IdStore::Wavelet { wt } => wt.size_bits() as u64,
        }
    }

    /// Bits per id — the Table-1 metric.
    pub fn bits_per_id(&self) -> f64 {
        self.id_bits() as f64 / self.n as f64
    }

    /// Vector payload size in bits (Fig. 3 numerator for PqCompressed).
    pub fn code_bits(&self) -> u64 {
        match &self.store {
            CodeStore::Flat(v) => v.len() as u64 * 32,
            CodeStore::Pq { pq, codes } => {
                (codes.len() / pq.m) as u64 * pq.code_bits() as u64
            }
            CodeStore::PqCompressed { bits, .. } => *bits,
        }
    }

    /// Search with coarse distances computed internally (pure rust).
    pub fn search(
        &self,
        query: &[f32],
        p: &SearchParams,
        scratch: &mut SearchScratch,
    ) -> Vec<(f32, u32)> {
        scratch.coarse.clear();
        crate::quant::dists_to_all(query, &self.centroids, self.dim, &mut scratch.coarse);
        self.search_with_coarse_inner(query, p, scratch)
    }

    /// Search with externally supplied coarse distances (the coordinator
    /// feeds PJRT-computed batches through this).
    pub fn search_with_coarse(
        &self,
        query: &[f32],
        coarse: &[f32],
        p: &SearchParams,
        scratch: &mut SearchScratch,
    ) -> Vec<(f32, u32)> {
        assert_eq!(coarse.len(), self.k);
        scratch.coarse.clear();
        scratch.coarse.extend_from_slice(coarse);
        self.search_with_coarse_inner(query, p, scratch)
    }

    fn search_with_coarse_inner(
        &self,
        query: &[f32],
        p: &SearchParams,
        scratch: &mut SearchScratch,
    ) -> Vec<(f32, u32)> {
        let nprobe = p.nprobe.min(self.k);
        // Select the nprobe nearest centroids.
        scratch.probe_order.clear();
        scratch.probe_order.extend(0..self.k as u32);
        let coarse = &scratch.coarse;
        scratch
            .probe_order
            .select_nth_unstable_by(nprobe.saturating_sub(1), |&a, &b| {
                coarse[a as usize].total_cmp(&coarse[b as usize])
            });
        let probes = &scratch.probe_order[..nprobe];

        let mut heap = TopK::new(p.k);
        // Prepare per-query LUT once for PQ stores.
        if let CodeStore::Pq { pq, .. } | CodeStore::PqCompressed { pq, .. } = &self.store {
            pq.lut(query, &mut scratch.lut);
        }

        let defer_ids = match &self.ids {
            IdStore::PerList { random_access, .. } => *random_access,
            IdStore::Wavelet { .. } => true,
        };

        for &c in probes {
            let c = c as usize;
            let (start, end) = (self.offsets[c], self.offsets[c + 1]);
            if start == end {
                continue;
            }
            // For non-random-access codecs (ROC) the whole list is decoded
            // now — the online-setting cost the paper measures.
            if !defer_ids {
                if let IdStore::PerList { codec, blobs, .. } = &self.ids {
                    scratch.ids.clear();
                    codec.decode(&blobs[c], self.n as u32, end - start, &mut scratch.ids);
                }
            }
            match &self.store {
                CodeStore::Flat(v) => {
                    for (o, row) in v[start * self.dim..end * self.dim]
                        .chunks_exact(self.dim)
                        .enumerate()
                    {
                        let d = l2_sq(query, row);
                        if d < heap.threshold() {
                            heap.push(d, self.payload(c, o, defer_ids, &scratch.ids));
                        }
                    }
                }
                CodeStore::Pq { pq, codes } => {
                    for (o, row) in codes[start * pq.m..end * pq.m].chunks_exact(pq.m).enumerate() {
                        let d = pq.adc(&scratch.lut, row);
                        if d < heap.threshold() {
                            heap.push(d, self.payload(c, o, defer_ids, &scratch.ids));
                        }
                    }
                }
                CodeStore::PqCompressed { pq, clusters, .. } => {
                    let codec = pcodes::ClusterCodeCodec::new(pq.ksub() as u32, pq.m);
                    let rows = end - start;
                    scratch.codes.clear();
                    scratch.codes.extend_from_slice(&codec.decode(&clusters[c], rows));
                    for (o, row) in scratch.codes.chunks_exact(pq.m).enumerate() {
                        let d = pq.adc(&scratch.lut, row);
                        if d < heap.threshold() {
                            heap.push(d, self.payload(c, o, defer_ids, &scratch.ids));
                        }
                    }
                }
            }
        }

        // Resolve payloads to ids.
        let winners = heap.into_sorted_u64();
        winners
            .into_iter()
            .map(|(d, payload)| {
                if defer_ids {
                    let c = (payload >> 32) as usize;
                    let o = (payload & 0xffff_ffff) as usize;
                    (d, self.resolve_id(c, o))
                } else {
                    (d, payload as u32)
                }
            })
            .collect()
    }

    #[inline]
    fn payload(&self, c: usize, o: usize, defer: bool, decoded: &[u32]) -> u64 {
        if defer {
            ((c as u64) << 32) | o as u64
        } else {
            decoded[o] as u64
        }
    }

    /// Resolve (cluster, offset) → id via the random-access store.
    fn resolve_id(&self, c: usize, o: usize) -> u32 {
        match &self.ids {
            IdStore::PerList { codec, blobs, .. } => codec
                .decode_nth(&blobs[c], self.n as u32, self.list_len(c), o)
                .expect("offset out of range"),
            IdStore::Wavelet { wt } => wt.select(c as u32, o as u64).expect("wt select") as u32,
        }
    }

    /// Decode the full id list of cluster `c` (tests, migration tooling).
    pub fn decode_list(&self, c: usize) -> Vec<u32> {
        let n = self.list_len(c);
        match &self.ids {
            IdStore::PerList { codec, blobs, .. } => {
                let mut out = Vec::with_capacity(n);
                codec.decode(&blobs[c], self.n as u32, n, &mut out);
                out
            }
            IdStore::Wavelet { wt } => {
                (0..n).map(|o| wt.select(c as u32, o as u64).unwrap() as u32).collect()
            }
        }
    }

    /// Name of the id store (bench labels).
    pub fn id_codec_name(&self) -> &str {
        match &self.ids {
            IdStore::PerList { codec, .. } => codec.name(),
            IdStore::Wavelet { wt: _ } => "wt",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{generate, groundtruth, Kind};

    fn build_ds() -> crate::datasets::Dataset {
        generate(Kind::DeepLike, 4000, 50, 16, 11)
    }

    fn check_search_quality(codec: &str, vectors: VectorMode, min_recall: f64) {
        let ds = build_ds();
        let params = IvfBuildParams {
            k: 64,
            id_codec: codec.into(),
            vectors,
            threads: 2,
            ..Default::default()
        };
        let idx = IvfIndex::build(&ds.data, ds.dim, &params);
        let gt = groundtruth::exact_knn(&ds.data, &ds.queries, ds.dim, 10, 2);
        let sp = SearchParams { nprobe: 16, k: 10 };
        let mut scratch = SearchScratch::default();
        let results: Vec<Vec<u32>> = (0..ds.nq)
            .map(|qi| idx.search(ds.query(qi), &sp, &mut scratch).into_iter().map(|(_, id)| id).collect())
            .collect();
        let recall = groundtruth::recall_at_k(&gt, 10, &results, 10);
        assert!(recall >= min_recall, "{codec} {:?}: recall={recall}", idx.id_codec_name());
    }

    #[test]
    fn all_id_codecs_same_results() {
        // Lossless id compression ⇒ identical search results across codecs
        // (the paper's reason for not reporting recall).
        let ds = build_ds();
        let sp = SearchParams { nprobe: 8, k: 10 };
        let mut baseline: Option<Vec<Vec<(f32, u32)>>> = None;
        for codec in ["unc64", "unc32", "compact", "ef", "roc", "wt", "wt1"] {
            let params = IvfBuildParams {
                k: 32,
                id_codec: codec.into(),
                threads: 2,
                ..Default::default()
            };
            let idx = IvfIndex::build(&ds.data, ds.dim, &params);
            let mut scratch = SearchScratch::default();
            let res: Vec<Vec<(f32, u32)>> =
                (0..20).map(|qi| idx.search(ds.query(qi), &sp, &mut scratch)).collect();
            match &baseline {
                None => baseline = Some(res),
                Some(b) => {
                    for (qi, (got, want)) in res.iter().zip(b).enumerate() {
                        let gd: Vec<u32> = got.iter().map(|r| r.1).collect();
                        let wd: Vec<u32> = want.iter().map(|r| r.1).collect();
                        assert_eq!(gd, wd, "codec={codec} query={qi}");
                    }
                }
            }
        }
    }

    #[test]
    fn flat_search_recall() {
        check_search_quality("roc", VectorMode::Flat, 0.85);
    }

    #[test]
    fn pq_search_recall() {
        check_search_quality("ef", VectorMode::Pq { m: 4, bits: 8 }, 0.5);
    }

    #[test]
    fn pq_compressed_matches_pq_results() {
        // Lossless code compression ⇒ identical distances to plain PQ.
        let ds = build_ds();
        let mk = |vectors| {
            IvfIndex::build(
                &ds.data,
                ds.dim,
                &IvfBuildParams {
                    k: 32,
                    id_codec: "compact".into(),
                    vectors,
                    threads: 2,
                    ..Default::default()
                },
            )
        };
        let a = mk(VectorMode::Pq { m: 4, bits: 8 });
        let b = mk(VectorMode::PqCompressed { m: 4, bits: 8 });
        let sp = SearchParams { nprobe: 8, k: 5 };
        let mut s1 = SearchScratch::default();
        let mut s2 = SearchScratch::default();
        for qi in 0..20 {
            let ra = a.search(ds.query(qi), &sp, &mut s1);
            let rb = b.search(ds.query(qi), &sp, &mut s2);
            assert_eq!(ra, rb, "query {qi}");
        }
        // And the compressed codes are no larger than plain ones (+streams
        // overhead is amortized at this size).
        assert!(b.code_bits() <= a.code_bits() + a.k as u64 * 64 * 4);
    }

    #[test]
    fn decoded_lists_form_partition() {
        let ds = build_ds();
        for codec in ["roc", "ef", "wt1"] {
            let idx = IvfIndex::build(
                &ds.data,
                ds.dim,
                &IvfBuildParams { k: 16, id_codec: codec.into(), threads: 2, ..Default::default() },
            );
            let mut seen = vec![false; ds.n];
            for c in 0..idx.k {
                for id in idx.decode_list(c) {
                    assert!(!seen[id as usize], "id {id} duplicated ({codec})");
                    seen[id as usize] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "missing ids ({codec})");
        }
    }

    #[test]
    fn bits_per_id_ordering() {
        // roc < ef < compact < unc64 on a reasonable IVF.
        let ds = build_ds();
        let bpe = |codec: &str| {
            IvfIndex::build(
                &ds.data,
                ds.dim,
                &IvfBuildParams { k: 16, id_codec: codec.into(), threads: 2, ..Default::default() },
            )
            .bits_per_id()
        };
        let (roc, ef, comp, unc) = (bpe("roc"), bpe("ef"), bpe("compact"), bpe("unc64"));
        assert!(roc < ef, "roc={roc} ef={ef}");
        assert!(ef < comp, "ef={ef} comp={comp}");
        assert!(comp < unc, "comp={comp} unc={unc}");
        assert_eq!(unc, 64.0);
    }
}
