//! HNSW (Malkov & Yashunin 2018) — used by Table 3's offline-compression
//! comparison (base layer only: "other levels occupy negligible storage").

use crate::graph::{beam_search, GraphStore, OrdF32, VisitedSet};
use crate::quant::l2_sq;
use crate::util::Rng;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

pub struct HnswParams {
    /// Base-layer degree bound (the paper's HNSW16..HNSW256 sweep).
    pub m: usize,
    pub ef_construction: usize,
    pub seed: u64,
}

impl Default for HnswParams {
    fn default() -> Self {
        HnswParams { m: 16, ef_construction: 100, seed: 7 }
    }
}

pub struct Hnsw {
    /// `layers[l][node]` — adjacency at level l (level 0 = base).
    pub layers: Vec<Vec<Vec<u32>>>,
    pub levels: Vec<u8>,
    pub entry: u32,
    pub dim: usize,
    m: usize,
}

impl Hnsw {
    pub fn build(data: &[f32], dim: usize, params: &HnswParams) -> Hnsw {
        let n = data.len() / dim;
        assert!(n > 0);
        let mut rng = Rng::new(params.seed);
        let ml = 1.0 / (params.m as f64).ln().max(0.7);
        let levels: Vec<u8> = (0..n)
            .map(|_| {
                let u: f64 = rng.f64().max(1e-12);
                ((-u.ln() * ml) as usize).min(12) as u8
            })
            .collect();
        let max_level = levels.iter().copied().max().unwrap() as usize;
        let mut layers: Vec<Vec<Vec<u32>>> =
            (0..=max_level).map(|_| vec![Vec::new(); n]).collect();
        let mut entry = 0u32;
        let mut entry_level = levels[0] as usize;

        let mut visited = VisitedSet::default();
        for i in 1..n {
            let q = &data[i * dim..(i + 1) * dim];
            let node_level = levels[i] as usize;
            let mut ep = entry;
            // Greedy descent above the node's level.
            for l in ((node_level + 1)..=entry_level).rev() {
                ep = greedy_closest(&layers[l], data, dim, q, ep);
            }
            // Insert at each level from min(node_level, entry_level) down.
            for l in (0..=node_level.min(entry_level)).rev() {
                let found = search_layer(
                    &layers[l],
                    data,
                    dim,
                    q,
                    ep,
                    params.ef_construction,
                    &mut visited,
                );
                let max_deg = if l == 0 { params.m } else { params.m / 2 + 1 };
                let selected = select_neighbors(&found, data, dim, max_deg);
                for &(_, nb) in &selected {
                    layers[l][i].push(nb);
                    layers[l][nb as usize].push(i as u32);
                    // Prune over-full neighbor.
                    if layers[l][nb as usize].len() > max_deg {
                        let nbv = &data[nb as usize * dim..(nb as usize + 1) * dim];
                        let cands: Vec<(f32, u32)> = layers[l][nb as usize]
                            .iter()
                            .map(|&x| {
                                (l2_sq(nbv, &data[x as usize * dim..(x as usize + 1) * dim]), x)
                            })
                            .collect();
                        layers[l][nb as usize] = select_neighbors(&cands, data, dim, max_deg)
                            .into_iter()
                            .map(|(_, x)| x)
                            .collect();
                    }
                }
                if let Some(&(_, best)) = selected.first() {
                    ep = best;
                }
            }
            if node_level > entry_level {
                entry = i as u32;
                entry_level = node_level;
            }
        }
        Hnsw { layers, levels, entry, dim, m: params.m }
    }

    /// Base-layer adjacency (what Table 3 compresses).
    pub fn base_adj(&self) -> &Vec<Vec<u32>> {
        &self.layers[0]
    }

    pub fn search(&self, data: &[f32], query: &[f32], ef: usize, k: usize) -> Vec<(f32, u32)> {
        let mut ep = self.entry;
        for l in (1..self.layers.len()).rev() {
            ep = greedy_closest(&self.layers[l], data, self.dim, query, ep);
        }
        let store = GraphStore::Raw(self.layers[0].clone());
        let mut visited = VisitedSet::default();
        let mut scratch = Vec::new();
        beam_search(&store, data, self.dim, &[ep], query, ef, k, &mut visited, &mut scratch)
    }

    pub fn num_base_edges(&self) -> u64 {
        self.layers[0].iter().map(|l| l.len() as u64).sum()
    }

    pub fn max_degree(&self) -> usize {
        self.m
    }
}

fn greedy_closest(layer: &[Vec<u32>], data: &[f32], dim: usize, q: &[f32], start: u32) -> u32 {
    let mut cur = start;
    let mut dcur = l2_sq(q, &data[cur as usize * dim..(cur as usize + 1) * dim]);
    loop {
        let mut improved = false;
        for &nb in &layer[cur as usize] {
            let d = l2_sq(q, &data[nb as usize * dim..(nb as usize + 1) * dim]);
            if d < dcur {
                dcur = d;
                cur = nb;
                improved = true;
            }
        }
        if !improved {
            return cur;
        }
    }
}

fn search_layer(
    layer: &[Vec<u32>],
    data: &[f32],
    dim: usize,
    q: &[f32],
    entry: u32,
    ef: usize,
    visited: &mut VisitedSet,
) -> Vec<(f32, u32)> {
    visited.clear(layer.len());
    let d0 = l2_sq(q, &data[entry as usize * dim..(entry as usize + 1) * dim]);
    let mut cand: BinaryHeap<Reverse<(OrdF32, u32)>> = BinaryHeap::new();
    let mut results = crate::quant::TopK::new(ef);
    cand.push(Reverse((OrdF32(d0), entry)));
    results.push(d0, entry);
    visited.insert(entry);
    while let Some(Reverse((OrdF32(d), node))) = cand.pop() {
        if d > results.threshold() {
            break;
        }
        for &nb in &layer[node as usize] {
            if visited.insert(nb) {
                let dn = l2_sq(q, &data[nb as usize * dim..(nb as usize + 1) * dim]);
                if dn < results.threshold() {
                    results.push(dn, nb);
                    cand.push(Reverse((OrdF32(dn), nb)));
                }
            }
        }
    }
    results.into_sorted()
}

/// HNSW heuristic neighbor selection (occlusion-pruned like MRNG).
fn select_neighbors(cands: &[(f32, u32)], data: &[f32], dim: usize, m: usize) -> Vec<(f32, u32)> {
    let mut sorted: Vec<(f32, u32)> = cands.to_vec();
    sorted.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    sorted.dedup_by_key(|c| c.1);
    let mut kept: Vec<(f32, u32)> = Vec::with_capacity(m);
    'outer: for &(dc, c) in &sorted {
        if kept.len() >= m {
            break;
        }
        let cv = &data[c as usize * dim..(c as usize + 1) * dim];
        for &(_, s) in &kept {
            if l2_sq(cv, &data[s as usize * dim..(s as usize + 1) * dim]) < dc {
                continue 'outer;
            }
        }
        kept.push((dc, c));
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{generate, groundtruth, Kind};

    #[test]
    fn degree_bounds_hold() {
        let ds = generate(Kind::DeepLike, 1000, 10, 12, 18);
        let h = Hnsw::build(&ds.data, ds.dim, &HnswParams { m: 12, ef_construction: 60, seed: 1 });
        for l in h.base_adj() {
            assert!(l.len() <= 12, "base degree {}", l.len());
        }
        assert!(h.num_base_edges() > 0);
    }

    #[test]
    fn search_recall_reasonable() {
        let ds = generate(Kind::DeepLike, 3000, 50, 16, 19);
        let h = Hnsw::build(&ds.data, ds.dim, &HnswParams { m: 16, ef_construction: 100, seed: 2 });
        let gt = groundtruth::exact_knn(&ds.data, &ds.queries, ds.dim, 10, 2);
        let results: Vec<Vec<u32>> = (0..ds.nq)
            .map(|qi| h.search(&ds.data, ds.query(qi), 64, 10).into_iter().map(|(_, id)| id).collect())
            .collect();
        let recall = groundtruth::nn_recall_at_k(&gt, 10, &results, 10);
        assert!(recall > 0.8, "recall={recall}");
    }

    #[test]
    fn base_layer_compresses_with_rec() {
        use crate::codecs::rec::{Rec, RecModel};
        let ds = generate(Kind::DeepLike, 800, 5, 8, 20);
        let h = Hnsw::build(&ds.data, ds.dim, &HnswParams { m: 8, ef_construction: 40, seed: 3 });
        let adj = h.base_adj();
        let e: u64 = adj.iter().map(|l| l.len() as u64).sum();
        let rec = Rec::new(RecModel::PolyaUrn);
        let enc = rec.encode_graph(adj);
        let got = rec.decode_graph(&enc.bytes, 800, e);
        let sort = |a: &[Vec<u32>]| -> Vec<Vec<u32>> {
            a.iter()
                .map(|l| {
                    let mut l = l.clone();
                    l.sort_unstable();
                    l
                })
                .collect()
        };
        assert_eq!(sort(&got), sort(adj));
    }
}
