//! NSG (Navigating Spreading-out Graph, Fu et al. 2017) — the paper's main
//! graph index (chosen there for its flat, non-hierarchical structure).
//!
//! Construction follows the paper's recipe at simulation scale: a kNN
//! graph provides candidates, edges are selected with the MRNG occlusion
//! rule (keep a candidate only if no already-kept neighbor is closer to it
//! than the node itself), degrees are capped at `r`, and connectivity from
//! the medoid is restored with a BFS + nearest-attachment pass.

use crate::graph::{beam_search, GraphStore, VisitedSet};
use crate::quant::l2_sq;
use crate::util::pool::parallel_map;

pub struct NsgParams {
    /// Maximum out-degree (the paper's NSG16..NSG256 sweep).
    pub r: usize,
    /// kNN-graph degree used for candidate generation.
    pub knn_k: usize,
    /// Occlusion slack (DiskANN-style α ≥ 1): a candidate c is occluded by
    /// a kept edge s only if `α·d(c,s) < d(i,c)`. α > 1 keeps the
    /// long-range edges that tightly-clustered collections need for
    /// navigability.
    pub alpha: f32,
    pub threads: usize,
    pub seed: u64,
}

impl Default for NsgParams {
    fn default() -> Self {
        NsgParams {
            r: 32,
            knn_k: 48,
            alpha: 1.2,
            threads: crate::util::pool::default_threads(),
            seed: 7,
        }
    }
}

pub struct Nsg {
    pub adj: Vec<Vec<u32>>,
    pub medoid: u32,
    /// Search entry set: medoid + farthest-point-sampled representatives.
    /// Tiny metadata (≤64 ids) that keeps island-like collections
    /// navigable; does not count toward the compressed id payload.
    pub entries: Vec<u32>,
    pub dim: usize,
}

impl Nsg {
    pub fn build(data: &[f32], dim: usize, params: &NsgParams) -> Nsg {
        let _n = data.len() / dim;
        let knn = super::knn::build(data, dim, params.knn_k.max(params.r), params.threads, params.seed);
        Self::build_from_knn(data, dim, &knn, params)
    }

    pub fn build_from_knn(data: &[f32], dim: usize, knn: &[Vec<u32>], params: &NsgParams) -> Nsg {
        let n = data.len() / dim;
        let medoid = find_medoid(data, dim, n);
        let entries = entry_set(data, dim, n, medoid, 64.min(n));

        // Candidate pool per node: kNN list + reverse kNN edges + the
        // visited set of a beam search from the medoid over the kNN graph
        // (the actual NSG candidate-acquisition step — it contributes the
        // long-range navigation edges that pure kNN pools lack on
        // clustered data).
        let mut reverse: Vec<Vec<u32>> = vec![Vec::new(); n];
        for (i, l) in knn.iter().enumerate() {
            for &j in l {
                if reverse[j as usize].len() < params.knn_k {
                    reverse[j as usize].push(i as u32);
                }
            }
        }
        let knn_store = GraphStore::Raw(knn.to_vec());
        let searched: Vec<Vec<u32>> = parallel_map(n, params.threads, |i| {
            let mut visited = VisitedSet::default();
            let mut scratch = Vec::new();
            beam_search(
                &knn_store,
                data,
                dim,
                &entries,
                &data[i * dim..(i + 1) * dim],
                64, // construction beam width: quality saturates ~64
                64,
                &mut visited,
                &mut scratch,
            )
            .into_iter()
            .map(|(_, id)| id)
            .collect()
        });

        let adj: Vec<Vec<u32>> = parallel_map(n, params.threads, |i| {
            let q = &data[i * dim..(i + 1) * dim];
            let mut cands: Vec<(f32, u32)> = knn[i]
                .iter()
                .chain(reverse[i].iter())
                .chain(searched[i].iter())
                .filter(|&&c| c != i as u32)
                .map(|&c| (l2_sq(q, &data[c as usize * dim..(c as usize + 1) * dim]), c))
                .collect();
            cands.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            cands.dedup_by_key(|c| c.1);
            // MRNG occlusion rule.
            let mut kept: Vec<(f32, u32)> = Vec::with_capacity(params.r);
            'outer: for &(dc, c) in &cands {
                if kept.len() >= params.r {
                    break;
                }
                let cv = &data[c as usize * dim..(c as usize + 1) * dim];
                for &(_, s) in &kept {
                    let sv = &data[s as usize * dim..(s as usize + 1) * dim];
                    // Squared distances: α² on the left ≙ α on metric dists.
                    if params.alpha * params.alpha * l2_sq(cv, sv) < dc {
                        continue 'outer; // occluded by a kept edge
                    }
                }
                kept.push((dc, c));
            }
            kept.into_iter().map(|(_, c)| c).collect()
        });

        let mut nsg = Nsg { adj, medoid, entries, dim };
        nsg.ensure_connectivity(data);
        nsg
    }

    /// Make every node reachable from the medoid: one bridging edge per
    /// unreachable *component* (NSG's spanning-tree step). The edge source
    /// is the reached node nearest to the component head among a bounded
    /// sample, so no single node's degree blows up.
    fn ensure_connectivity(&mut self, data: &[f32]) {
        let n = self.adj.len();
        let mut seen = vec![false; n];
        let mut queue = std::collections::VecDeque::new();
        let mut reached_sample: Vec<u32> = Vec::new();
        let bfs = |adj: &Vec<Vec<u32>>,
                   seen: &mut Vec<bool>,
                   queue: &mut std::collections::VecDeque<u32>,
                   sample: &mut Vec<u32>| {
            while let Some(u) = queue.pop_front() {
                if sample.len() < 512 || u as usize % 64 == 0 {
                    sample.push(u);
                }
                for &v in &adj[u as usize] {
                    if !seen[v as usize] {
                        seen[v as usize] = true;
                        queue.push_back(v);
                    }
                }
            }
        };
        seen[self.medoid as usize] = true;
        queue.push_back(self.medoid);
        bfs(&self.adj, &mut seen, &mut queue, &mut reached_sample);
        for i in 0..n {
            if seen[i] {
                continue;
            }
            // Bridge from the nearest sampled reached node to this
            // component head, then absorb the whole component via BFS.
            let q = &data[i * self.dim..(i + 1) * self.dim];
            let mut best = (f32::INFINITY, self.medoid);
            for &s in &reached_sample {
                let d = l2_sq(q, &data[s as usize * self.dim..(s as usize + 1) * self.dim]);
                if d < best.0 {
                    best = (d, s);
                }
            }
            self.adj[best.1 as usize].push(i as u32);
            seen[i] = true;
            queue.push_back(i as u32);
            bfs(&self.adj, &mut seen, &mut queue, &mut reached_sample);
        }
    }

    /// Search through a (possibly compressed) adjacency store.
    pub fn search_store(
        &self,
        store: &GraphStore,
        data: &[f32],
        query: &[f32],
        ef: usize,
        k: usize,
        visited: &mut VisitedSet,
        scratch: &mut Vec<u32>,
    ) -> Vec<(f32, u32)> {
        beam_search(store, data, self.dim, &self.entries, query, ef, k, visited, scratch)
    }

    pub fn search(&self, data: &[f32], query: &[f32], ef: usize, k: usize) -> Vec<(f32, u32)> {
        // Convenience wrapper over a borrowed raw store.
        let store = GraphStore::Raw(self.adj.clone());
        let mut visited = VisitedSet::default();
        let mut scratch = Vec::new();
        self.search_store(&store, data, query, ef, k, &mut visited, &mut scratch)
    }

    pub fn num_edges(&self) -> u64 {
        self.adj.iter().map(|l| l.len() as u64).sum()
    }
}

/// Farthest-point sampling over a bounded subsample: `count` spread-out
/// entry points, starting from the medoid.
fn entry_set(data: &[f32], dim: usize, n: usize, medoid: u32, count: usize) -> Vec<u32> {
    let mut rng = crate::util::Rng::new(0xe17e);
    let sample: Vec<u32> = if n <= 4096 {
        (0..n as u32).collect()
    } else {
        (0..4096).map(|_| rng.below(n as u64) as u32).collect()
    };
    let mut chosen = vec![medoid];
    let mut min_d: Vec<f32> = sample
        .iter()
        .map(|&s| {
            l2_sq(
                &data[s as usize * dim..(s as usize + 1) * dim],
                &data[medoid as usize * dim..(medoid as usize + 1) * dim],
            )
        })
        .collect();
    while chosen.len() < count {
        let (best_i, best_d) = min_d
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, &d)| (i, d))
            .unwrap();
        if best_d <= 0.0 {
            break;
        }
        let p = sample[best_i];
        chosen.push(p);
        let pv = &data[p as usize * dim..(p as usize + 1) * dim];
        for (i, &s) in sample.iter().enumerate() {
            let d = l2_sq(&data[s as usize * dim..(s as usize + 1) * dim], pv);
            if d < min_d[i] {
                min_d[i] = d;
            }
        }
    }
    chosen
}

fn find_medoid(data: &[f32], dim: usize, n: usize) -> u32 {
    // Nearest point to the global mean.
    let mut mean = vec![0f64; dim];
    for row in data.chunks_exact(dim) {
        for (m, &v) in mean.iter_mut().zip(row) {
            *m += v as f64;
        }
    }
    let meanf: Vec<f32> = mean.iter().map(|&m| (m / n as f64) as f32).collect();
    crate::quant::nearest(&meanf, data, dim).0 as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{generate, groundtruth, Kind};

    #[test]
    fn builds_within_degree_cap_and_connected() {
        let ds = generate(Kind::DeepLike, 1500, 20, 12, 15);
        let nsg = Nsg::build(&ds.data, ds.dim, &NsgParams { r: 16, knn_k: 24, threads: 2, seed: 1, ..Default::default() });
        for l in &nsg.adj {
            // +small slack from connectivity attachment
            assert!(l.len() <= 16 + 4, "degree {}", l.len());
        }
        // Connectivity: BFS reaches everything.
        let mut seen = vec![false; 1500];
        let mut q = std::collections::VecDeque::from([nsg.medoid]);
        seen[nsg.medoid as usize] = true;
        let mut count = 1;
        while let Some(u) = q.pop_front() {
            for &v in &nsg.adj[u as usize] {
                if !seen[v as usize] {
                    seen[v as usize] = true;
                    count += 1;
                    q.push_back(v);
                }
            }
        }
        assert_eq!(count, 1500);
    }

    #[test]
    fn search_recall_reasonable() {
        let ds = generate(Kind::DeepLike, 3000, 50, 16, 16);
        let nsg = Nsg::build(&ds.data, ds.dim, &NsgParams { r: 24, knn_k: 32, threads: 2, seed: 2, ..Default::default() });
        let gt = groundtruth::exact_knn(&ds.data, &ds.queries, ds.dim, 10, 2);
        let results: Vec<Vec<u32>> = (0..ds.nq)
            .map(|qi| {
                nsg.search(&ds.data, ds.query(qi), 64, 10).into_iter().map(|(_, id)| id).collect()
            })
            .collect();
        let recall = groundtruth::nn_recall_at_k(&gt, 10, &results, 10);
        assert!(recall > 0.75, "recall={recall}");
    }

    #[test]
    fn compressed_stores_give_identical_results() {
        let ds = generate(Kind::DeepLike, 1200, 15, 12, 17);
        let nsg = Nsg::build(&ds.data, ds.dim, &NsgParams { r: 16, knn_k: 24, threads: 2, seed: 3, ..Default::default() });
        let raw = GraphStore::Raw(nsg.adj.clone());
        let mut visited = VisitedSet::default();
        let mut scratch = Vec::new();
        for codec in ["compact", "ef", "roc"] {
            let comp = GraphStore::compress(&nsg.adj, codec);
            for qi in 0..ds.nq {
                let a = nsg.search_store(&raw, &ds.data, ds.query(qi), 32, 5, &mut visited, &mut scratch);
                let b = nsg.search_store(&comp, &ds.data, ds.query(qi), 32, 5, &mut visited, &mut scratch);
                let ai: Vec<u32> = a.iter().map(|r| r.1).collect();
                let bi: Vec<u32> = b.iter().map(|r| r.1).collect();
                assert_eq!(ai, bi, "codec={codec} q={qi}");
            }
        }
    }
}
