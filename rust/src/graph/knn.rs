//! kNN-graph construction — the substrate both NSG and HNSW quality checks
//! build on. Exact (brute force, parallel) for small collections, IVF-
//! assisted approximate for large ones.

use crate::index::{IvfBuildParams, IvfIndex, SearchParams, SearchScratch};
use crate::quant::top_k;
use crate::util::pool::parallel_map;

/// Exact kNN graph (excluding self), parallel brute force. O(N² d).
pub fn exact(data: &[f32], dim: usize, k: usize, threads: usize) -> Vec<Vec<u32>> {
    let n = data.len() / dim;
    parallel_map(n, threads, |i| {
        let q = &data[i * dim..(i + 1) * dim];
        top_k(q, data, dim, k + 1)
            .into_iter()
            .filter(|&(_, id)| id != i as u32)
            .take(k)
            .map(|(_, id)| id)
            .collect()
    })
}

/// Approximate kNN graph via a scaffold IVF index: each point queries the
/// index with a generous nprobe. Recall is high because points and
/// database coincide.
pub fn approximate(data: &[f32], dim: usize, k: usize, threads: usize, seed: u64) -> Vec<Vec<u32>> {
    let n = data.len() / dim;
    let kc = ((n as f64).sqrt() as usize).clamp(8, 4096);
    let params = IvfBuildParams {
        k: kc,
        train_iters: 6,
        seed,
        threads,
        id_codec: "unc32".into(),
        ..Default::default()
    };
    let index = IvfIndex::build(data, dim, &params);
    let sp = SearchParams { nprobe: 12.min(kc), k: k + 1 };
    parallel_map(n, threads, |i| {
        let mut scratch = SearchScratch::default();
        index
            .search(&data[i * dim..(i + 1) * dim], &sp, &mut scratch)
            .into_iter()
            .filter(|&(_, id)| id != i as u32)
            .take(k)
            .map(|(_, id)| id)
            .collect()
    })
}

/// Auto-select: exact below a size threshold, approximate above.
pub fn build(data: &[f32], dim: usize, k: usize, threads: usize, seed: u64) -> Vec<Vec<u32>> {
    let n = data.len() / dim;
    if n <= 20_000 {
        exact(data, dim, k, threads)
    } else {
        approximate(data, dim, k, threads, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{generate, Kind};

    #[test]
    fn exact_graph_is_symmetric_quality() {
        let ds = generate(Kind::DeepLike, 600, 5, 8, 13);
        let g = exact(&ds.data, ds.dim, 5, 2);
        assert_eq!(g.len(), 600);
        for (i, l) in g.iter().enumerate() {
            assert_eq!(l.len(), 5);
            assert!(!l.contains(&(i as u32)), "self edge at {i}");
            let d: std::collections::HashSet<_> = l.iter().collect();
            assert_eq!(d.len(), 5, "dup edges at {i}");
        }
    }

    #[test]
    fn approximate_matches_exact_mostly() {
        let ds = generate(Kind::DeepLike, 2000, 5, 12, 14);
        let ex = exact(&ds.data, ds.dim, 8, 2);
        let ap = approximate(&ds.data, ds.dim, 8, 2, 1);
        let mut inter = 0usize;
        let mut total = 0usize;
        for (e, a) in ex.iter().zip(&ap) {
            let s: std::collections::HashSet<_> = e.iter().collect();
            inter += a.iter().filter(|id| s.contains(id)).count();
            total += e.len();
        }
        let recall = inter as f64 / total as f64;
        assert!(recall > 0.8, "knn-graph recall={recall}");
    }
}
