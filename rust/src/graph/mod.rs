//! Graph-based ANN indexes (NSG, HNSW) with compressed adjacency storage.
//!
//! The friend lists `e_i` are sets of target ids (Fig. 1 bottom); at
//! search time only *sequential* access within a visited node's list is
//! needed, so per-node compressed streams (ROC, EF, …) apply — the NSG
//! rows of Tables 1 and 2.  Whole-graph offline compression (REC,
//! Zuckerli) lives in `codecs::{rec, zuckerli}` and is exercised over
//! these graphs by Table 3.

pub mod knn;
pub mod nsg;
pub mod hnsw;

use crate::codecs::{CodecSpec, IdCodec};
use crate::util::bytes::{Blobs, BlobsBuilder};

/// Adjacency storage: raw lists or one compressed stream per node (all
/// streams laid end-to-end in one shared [`Blobs`] buffer, so a persisted
/// graph index reopens them zero-copy).
pub enum GraphStore {
    Raw(Vec<Vec<u32>>),
    Compressed {
        codec: Box<dyn IdCodec>,
        blobs: Blobs,
        lens: Vec<u32>,
        universe: u32,
        bits: u64,
    },
}

impl GraphStore {
    /// Compress raw adjacency with a per-list codec (panics on an invalid
    /// name — library-internal callers pass registry constants; fallible
    /// boundaries go through [`GraphStore::try_compress`]).
    pub fn compress(adj: &[Vec<u32>], codec_name: &str) -> GraphStore {
        let spec = CodecSpec::parse(codec_name).unwrap_or_else(|e| panic!("{e}"));
        Self::try_compress(adj, &spec).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Compress raw adjacency with a parsed per-list codec spec.
    pub fn try_compress(adj: &[Vec<u32>], spec: &CodecSpec) -> anyhow::Result<GraphStore> {
        let codec = spec.id_codec()?;
        let universe = adj.len() as u32;
        let mut bits = 0u64;
        let mut lens = Vec::with_capacity(adj.len());
        let mut blobs = BlobsBuilder::new();
        for l in adj {
            let enc = codec.encode(l, universe);
            bits += enc.bits;
            lens.push(l.len() as u32);
            blobs.push(&enc.bytes);
        }
        Ok(GraphStore::Compressed { codec, blobs: blobs.finish(), lens, universe, bits })
    }

    /// Reassemble a compressed store from persisted parts (the open path:
    /// `blobs` borrows the file buffer, so no stream is copied or
    /// re-coded).
    pub fn from_compressed_parts(
        spec: &CodecSpec,
        blobs: Blobs,
        lens: Vec<u32>,
        universe: u32,
        bits: u64,
    ) -> anyhow::Result<GraphStore> {
        let codec = spec.id_codec()?;
        anyhow::ensure!(
            blobs.count() == lens.len(),
            "adjacency store holds {} blobs for {} nodes",
            blobs.count(),
            lens.len()
        );
        Ok(GraphStore::Compressed { codec, blobs, lens, universe, bits })
    }

    /// Friend list of node `i`, decoded into `scratch` if compressed.
    /// Returns a slice valid until the next call.
    #[inline]
    pub fn neighbors<'a>(&'a self, i: usize, scratch: &'a mut Vec<u32>) -> &'a [u32] {
        match self {
            GraphStore::Raw(adj) => &adj[i],
            GraphStore::Compressed { codec, blobs, lens, universe, .. } => {
                scratch.clear();
                codec.decode(blobs.get(i), *universe, lens[i] as usize, scratch);
                scratch
            }
        }
    }

    /// Software-prefetch node `i`'s adjacency block (the compressed
    /// stream, or the raw list) into L1. Beam search issues this for the
    /// best pending candidate while the current node's neighbors are
    /// being scored, hiding the dependent-load latency of the next hop.
    /// Purely advisory — results are untouched.
    #[inline]
    pub fn prefetch_adjacency(&self, i: usize) {
        match self {
            GraphStore::Raw(adj) => crate::simd::prefetch_read(adj[i].as_ptr()),
            GraphStore::Compressed { blobs, .. } => {
                let blob = blobs.get(i);
                crate::simd::prefetch_read(blob.as_ptr());
                if blob.len() > 64 {
                    crate::simd::prefetch_read(blob[64..].as_ptr());
                }
            }
        }
    }

    pub fn num_nodes(&self) -> usize {
        match self {
            GraphStore::Raw(adj) => adj.len(),
            GraphStore::Compressed { lens, .. } => lens.len(),
        }
    }

    pub fn num_edges(&self) -> u64 {
        match self {
            GraphStore::Raw(adj) => adj.iter().map(|l| l.len() as u64).sum(),
            GraphStore::Compressed { lens, .. } => lens.iter().map(|&l| l as u64).sum(),
        }
    }

    /// Exact id payload bits (Table-1 NSG numerator). Raw lists count as
    /// 32 bits/edge, the Faiss graph default.
    pub fn id_bits(&self) -> u64 {
        match self {
            GraphStore::Raw(adj) => adj.iter().map(|l| l.len() as u64 * 32).sum(),
            GraphStore::Compressed { bits, .. } => *bits,
        }
    }

    pub fn bits_per_edge(&self) -> f64 {
        self.id_bits() as f64 / self.num_edges() as f64
    }

    /// Decode every friend list once through the fallible codec path, so
    /// structural corruption surfaces as an open-time error instead of a
    /// panic mid-query. Called when a legacy (unchecksummed) container is
    /// opened — checksummed containers already verified their bytes.
    pub fn validate_decode(&self) -> anyhow::Result<()> {
        use anyhow::Context as _;
        match self {
            GraphStore::Raw(adj) => {
                let n = adj.len() as u64;
                for (i, l) in adj.iter().enumerate() {
                    if let Some(&bad) = l.iter().find(|&&t| t as u64 >= n) {
                        anyhow::bail!("node {i}: neighbor {bad} out of range (n={n})");
                    }
                }
                Ok(())
            }
            GraphStore::Compressed { codec, blobs, lens, universe, .. } => {
                let mut scratch = crate::codecs::DecodeScratch::default();
                let mut out = Vec::new();
                for (i, &len) in lens.iter().enumerate() {
                    out.clear();
                    codec
                        .try_decode_into(blobs.get(i), *universe, len as usize, &mut out, &mut scratch)
                        .with_context(|| format!("friend list of node {i} failed to decode"))?;
                }
                Ok(())
            }
        }
    }
}

/// Greedy best-first beam search over any [`GraphStore`] — the shared
/// search routine of NSG and (base-layer) HNSW.
///
/// `entries` may hold several seeds (NSG uses a farthest-point-sampled
/// entry set so island-like collections stay navigable); returns up to
/// `k` (dist, id) pairs, ascending.
pub fn beam_search(
    store: &GraphStore,
    data: &[f32],
    dim: usize,
    entries: &[u32],
    query: &[f32],
    ef: usize,
    k: usize,
    visited: &mut VisitedSet,
    scratch: &mut Vec<u32>,
) -> Vec<(f32, u32)> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    visited.clear(store.num_nodes());
    // Candidates: min-heap by distance; results: bounded max-heap.
    let mut cand: BinaryHeap<Reverse<(OrdF32, u32)>> = BinaryHeap::new();
    let mut results = crate::quant::TopK::new(ef.max(k));
    for &entry in entries {
        if visited.insert(entry) {
            let d0 = crate::quant::l2_sq(
                query,
                &data[entry as usize * dim..(entry as usize + 1) * dim],
            );
            cand.push(Reverse((OrdF32(d0), entry)));
            results.push(d0, entry);
        }
    }

    while let Some(Reverse((OrdF32(d), node))) = cand.pop() {
        if d > results.threshold() {
            break;
        }
        // Overlap the next hop's dependent load with this node's scoring.
        if let Some(Reverse((_, next))) = cand.peek() {
            store.prefetch_adjacency(*next as usize);
        }
        // Sequential access to the friend list: decode the node's stream.
        let neigh = store.neighbors(node as usize, scratch);
        // First pass: prefetch every neighbor's vector row; the distance
        // loop below then hits warm lines instead of serial cache misses.
        for &nb in neigh {
            crate::simd::prefetch_read(data[nb as usize * dim..].as_ptr());
        }
        for &nb in neigh {
            if visited.insert(nb) {
                let dn =
                    crate::quant::l2_sq(query, &data[nb as usize * dim..(nb as usize + 1) * dim]);
                if dn < results.threshold() {
                    results.push(dn, nb);
                    cand.push(Reverse((OrdF32(dn), nb)));
                }
            }
        }
    }
    let mut out = results.into_sorted();
    out.truncate(k);
    out
}

/// Total-ordered f32 wrapper for heaps.
#[derive(PartialEq, Clone, Copy)]
pub struct OrdF32(pub f32);

impl Eq for OrdF32 {}

impl PartialOrd for OrdF32 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF32 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Epoch-based visited set: O(1) clear between queries.
#[derive(Default)]
pub struct VisitedSet {
    epoch: u32,
    marks: Vec<u32>,
}

impl VisitedSet {
    pub fn clear(&mut self, n: usize) {
        if self.marks.len() < n {
            self.marks = vec![0; n];
            self.epoch = 1;
        } else {
            self.epoch += 1;
            if self.epoch == 0 {
                self.marks.fill(0);
                self.epoch = 1;
            }
        }
    }

    /// Returns true if newly inserted.
    #[inline]
    pub fn insert(&mut self, i: u32) -> bool {
        let m = &mut self.marks[i as usize];
        if *m == self.epoch {
            false
        } else {
            *m = self.epoch;
            true
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn visited_set_epochs() {
        let mut v = VisitedSet::default();
        v.clear(10);
        assert!(v.insert(3));
        assert!(!v.insert(3));
        v.clear(10);
        assert!(v.insert(3), "cleared by epoch bump");
    }

    #[test]
    fn graph_store_roundtrip_and_bits() {
        let mut rng = Rng::new(90);
        let adj: Vec<Vec<u32>> = (0..100)
            .map(|_| rng.sample_distinct(100, 10).into_iter().map(|v| v as u32).collect())
            .collect();
        let raw = GraphStore::Raw(adj.clone());
        for codec in ["compact", "ef", "roc", "unc32"] {
            let comp = GraphStore::compress(&adj, codec);
            assert_eq!(comp.num_edges(), raw.num_edges());
            let mut scratch = Vec::new();
            for i in 0..100 {
                let mut got: Vec<u32> = comp.neighbors(i, &mut scratch).to_vec();
                got.sort_unstable();
                let mut want = adj[i].clone();
                want.sort_unstable();
                assert_eq!(got, want, "{codec} node {i}");
            }
        }
        assert_eq!(raw.bits_per_edge(), 32.0);
    }
}
