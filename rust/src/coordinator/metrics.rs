//! Serving metrics: query/batch counters, a latency histogram and the
//! admission-queue depth high-water mark — exposed both as the
//! human-readable [`Metrics::summary`] line and machine-readable JSON
//! ([`Metrics::metrics_json`]) so benches and CI gates parse a contract,
//! not a log format.
//!
//! `Metrics` is a thin view over cells in the global [`crate::obs`]
//! registry: every counter here is also a `zann_*` series (labelled
//! `coord="<n>"` so concurrently-live coordinators never alias) that
//! `Registry::render_prometheus()` / `render_json()` expose. The latency
//! store is the lock-free log₂ [`crate::obs::Histogram`] — the old
//! `Mutex<Vec<u64>>` could be poisoned by a caught worker panic, and its
//! unbounded growth made every percentile call clone-and-sort the full
//! history. Percentiles are now nearest-rank over the histogram and
//! report the selected bucket's upper bound (a ≤2× overestimate with
//! power-of-two buckets; the summary/JSON key names are unchanged).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::obs::{self, Counter, Gauge, Histogram};

/// Distinguishes coordinator instances on the shared global registry.
static COORD_SEQ: AtomicU64 = AtomicU64::new(0);

pub struct Metrics {
    queries: Arc<Counter>,
    batches: Arc<Counter>,
    pjrt_queries: Arc<Counter>,
    batch_fill: Arc<Counter>,
    timeouts: Arc<Counter>,
    rejections: Arc<Counter>,
    worker_panics: Arc<Counter>,
    /// Requests currently sitting in the admission queue (enqueued, not
    /// yet pulled by the batcher).
    queue_depth: Arc<Gauge>,
    /// High-water mark of `queue_depth` over the coordinator's lifetime.
    queue_hwm: Arc<Gauge>,
    latency_us: Arc<Histogram>,
}

impl Metrics {
    pub fn new() -> Self {
        let seq = COORD_SEQ.fetch_add(1, Ordering::Relaxed).to_string();
        let l: [(&'static str, &str); 1] = [("coord", &seq)];
        Metrics {
            queries: obs::counter("zann_queries_total", &l),
            batches: obs::counter("zann_batches_total", &l),
            pjrt_queries: obs::counter("zann_pjrt_queries_total", &l),
            batch_fill: obs::counter("zann_batch_fill_total", &l),
            timeouts: obs::counter("zann_timeouts_total", &l),
            rejections: obs::counter("zann_rejections_total", &l),
            worker_panics: obs::counter("zann_worker_panics_total", &l),
            queue_depth: obs::gauge("zann_queue_depth", &l),
            queue_hwm: obs::gauge("zann_queue_hwm", &l),
            latency_us: obs::histogram("zann_query_latency_us", &l),
        }
    }

    pub fn record_batch(&self, fill: usize) {
        self.batches.inc();
        self.batch_fill.add(fill as u64);
    }

    pub fn record_query(&self, latency: Duration, via_pjrt: bool) {
        self.queries.inc();
        if via_pjrt {
            self.pjrt_queries.inc();
        }
        self.latency_us.observe(latency.as_micros() as u64);
    }

    /// A request aged past its deadline before a worker reached it.
    pub fn record_timeout(&self) {
        self.timeouts.inc();
    }

    /// A request bounced off the full admission queue.
    pub fn record_rejection(&self) {
        self.rejections.inc();
    }

    /// A panic was caught while serving one request (or the batcher
    /// itself was respawned after one).
    pub fn record_worker_panic(&self) {
        self.worker_panics.inc();
    }

    /// A request was accepted into the admission queue. Updates the
    /// queue-depth high-water mark.
    pub fn record_enqueue(&self) {
        let depth = self.queue_depth.add(1);
        self.queue_hwm.max_of(depth);
    }

    /// The batcher pulled a request off the admission queue.
    pub fn record_dequeue(&self) {
        // Floored at zero: a respawned batcher may drain requests
        // enqueued before a mid-batch panic reset its view of the world.
        self.queue_depth.sub_floor0(1);
    }

    pub fn queries(&self) -> u64 {
        self.queries.get()
    }

    pub fn batches(&self) -> u64 {
        self.batches.get()
    }

    pub fn timeouts(&self) -> u64 {
        self.timeouts.get()
    }

    pub fn rejections(&self) -> u64 {
        self.rejections.get()
    }

    pub fn worker_panics(&self) -> u64 {
        self.worker_panics.get()
    }

    /// Deepest the admission queue ever got (0 when nothing ever waited).
    pub fn queue_depth_hwm(&self) -> u64 {
        self.queue_hwm.get().max(0) as u64
    }

    pub fn pjrt_fraction(&self) -> f64 {
        let q = self.queries().max(1);
        self.pjrt_queries.get() as f64 / q as f64
    }

    pub fn mean_batch_fill(&self) -> f64 {
        let b = self.batches().max(1);
        self.batch_fill.get() as f64 / b as f64
    }

    /// Latency percentile in microseconds (p in [0, 100]). Nearest-rank
    /// over the log₂ histogram; the value is the upper bound of the
    /// selected bucket (`2^i − 1` µs).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        self.latency_us.quantile(p / 100.0)
    }

    pub fn summary(&self) -> String {
        format!(
            "queries={} batches={} mean_fill={:.1} pjrt={:.0}% p50={}us p95={}us p99={}us \
             timeouts={} rejections={} worker_panics={} queue_hwm={}",
            self.queries(),
            self.batches(),
            self.mean_batch_fill(),
            100.0 * self.pjrt_fraction(),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(95.0),
            self.latency_percentile_us(99.0),
            self.timeouts(),
            self.rejections(),
            self.worker_panics(),
            self.queue_depth_hwm(),
        )
    }

    /// Machine-readable view of [`Metrics::summary`] — the same counters
    /// as one JSON object, so `zann serve --metrics-json` and the serve
    /// bench emit a contract instead of making CI scrape the summary line.
    pub fn metrics_json(&self) -> String {
        format!(
            "{{\"queries\": {}, \"batches\": {}, \"mean_batch_fill\": {:.3}, \
             \"pjrt_fraction\": {:.6}, \"p50_us\": {}, \"p95_us\": {}, \"p99_us\": {}, \
             \"timeouts\": {}, \"rejections\": {}, \"worker_panics\": {}, \"queue_hwm\": {}}}",
            self.queries(),
            self.batches(),
            self.mean_batch_fill(),
            self.pjrt_fraction(),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(95.0),
            self.latency_percentile_us(99.0),
            self.timeouts(),
            self.rejections(),
            self.worker_panics(),
            self.queue_depth_hwm(),
        )
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_counters() {
        let m = Metrics::default();
        for i in 1..=100u64 {
            m.record_query(Duration::from_micros(i), i % 2 == 0);
        }
        m.record_batch(10);
        assert_eq!(m.queries(), 100);
        assert_eq!(m.batches(), 1);
        assert!((m.pjrt_fraction() - 0.5).abs() < 1e-9);
        assert_eq!(m.mean_batch_fill(), 10.0);
        // Log₂-bucket percentiles report the bucket's upper bound. For
        // 1..=100µs the cumulative bucket counts are 1, 3, 7, 15, 31,
        // 63, 100, so rank 50 (the median) lands in the 32..=63 bucket
        // → 63, and the max lands in 64..=127 → 127.
        assert_eq!(m.latency_percentile_us(50.0), 63);
        assert_eq!(m.latency_percentile_us(100.0), 127);
        assert!(m.summary().contains("queries=100"));
    }

    #[test]
    fn degradation_counters() {
        let m = Metrics::default();
        m.record_timeout();
        m.record_timeout();
        m.record_rejection();
        m.record_worker_panic();
        assert_eq!(m.timeouts(), 2);
        assert_eq!(m.rejections(), 1);
        assert_eq!(m.worker_panics(), 1);
        let s = m.summary();
        assert!(s.contains("timeouts=2") && s.contains("rejections=1"), "{s}");
        assert!(s.contains("worker_panics=1"), "{s}");
    }

    #[test]
    fn empty_metrics() {
        let m = Metrics::default();
        assert_eq!(m.latency_percentile_us(99.0), 0);
        assert_eq!(m.pjrt_fraction(), 0.0);
        assert_eq!(m.queue_depth_hwm(), 0);
    }

    #[test]
    fn instances_do_not_share_counters() {
        // Both live on the same global registry, so the coord label must
        // keep them apart.
        let a = Metrics::default();
        let b = Metrics::default();
        a.record_timeout();
        assert_eq!(a.timeouts(), 1);
        assert_eq!(b.timeouts(), 0);
    }

    #[test]
    fn cross_thread_recording_survives_a_panicking_recorder() {
        // The old Mutex<Vec> histogram could be poisoned by a panic
        // between lock() and push(); the lock-free histogram has no such
        // failure mode. Simulate the worst case: a thread panics while
        // holding nothing, mid-record, and percentiles keep working.
        let m = Arc::new(Metrics::default());
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            m2.record_query(Duration::from_micros(10), false);
            panic!("worker dies after recording");
        })
        .join();
        m.record_query(Duration::from_micros(10), false);
        assert_eq!(m.queries(), 2);
        assert_eq!(m.latency_percentile_us(50.0), 15, "10µs sits in the 8..=15 bucket");
    }

    #[test]
    fn queue_hwm_tracks_peak_not_current_depth() {
        let m = Metrics::default();
        m.record_enqueue();
        m.record_enqueue();
        m.record_enqueue();
        m.record_dequeue();
        m.record_dequeue();
        assert_eq!(m.queue_depth_hwm(), 3, "hwm is the peak, not the current depth");
        m.record_enqueue();
        assert_eq!(m.queue_depth_hwm(), 3, "re-filling below the peak leaves the hwm");
        // Saturation: extra dequeues (batcher respawn) never underflow.
        for _ in 0..10 {
            m.record_dequeue();
        }
        m.record_enqueue();
        assert_eq!(m.queue_depth_hwm(), 3);
        assert!(m.summary().contains("queue_hwm=3"));
    }

    #[test]
    fn metrics_json_is_wellformed_and_complete() {
        let m = Metrics::default();
        m.record_query(Duration::from_micros(120), false);
        m.record_batch(1);
        m.record_timeout();
        m.record_rejection();
        m.record_enqueue();
        let j = m.metrics_json();
        for key in [
            "\"queries\"",
            "\"batches\"",
            "\"mean_batch_fill\"",
            "\"pjrt_fraction\"",
            "\"p50_us\"",
            "\"p95_us\"",
            "\"p99_us\"",
            "\"timeouts\"",
            "\"rejections\"",
            "\"worker_panics\"",
            "\"queue_hwm\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        crate::obs::expo::check_json_shape(&j).expect("metrics_json must be well-formed");
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"rejections\": 1") && j.contains("\"queue_hwm\": 1"), "{j}");
    }

    #[test]
    fn metrics_are_visible_on_the_global_registry_when_obs_is_on() {
        let m = Metrics::default();
        m.record_query(Duration::from_micros(7), false);
        if crate::obs::enabled() {
            let text = crate::obs::global().render_prometheus();
            assert!(text.contains("zann_queries_total"), "registry must carry coordinator series");
            assert!(text.contains("zann_query_latency_us_count"), "{}", &text[..text.len().min(400)]);
        }
    }
}
