//! Serving metrics: query/batch counters, a latency histogram and the
//! admission-queue depth high-water mark — exposed both as the
//! human-readable [`Metrics::summary`] line and machine-readable JSON
//! ([`Metrics::metrics_json`]) so benches and CI gates parse a contract,
//! not a log format.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

#[derive(Default)]
pub struct Metrics {
    queries: AtomicU64,
    batches: AtomicU64,
    pjrt_queries: AtomicU64,
    batch_fill: AtomicU64,
    timeouts: AtomicU64,
    rejections: AtomicU64,
    worker_panics: AtomicU64,
    /// Requests currently sitting in the admission queue (enqueued, not
    /// yet pulled by the batcher).
    queue_depth: AtomicU64,
    /// High-water mark of `queue_depth` over the coordinator's lifetime.
    queue_hwm: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
}

impl Metrics {
    pub fn record_batch(&self, fill: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_fill.fetch_add(fill as u64, Ordering::Relaxed);
    }

    pub fn record_query(&self, latency: Duration, via_pjrt: bool) {
        self.queries.fetch_add(1, Ordering::Relaxed);
        if via_pjrt {
            self.pjrt_queries.fetch_add(1, Ordering::Relaxed);
        }
        // A caught worker panic may have poisoned the histogram lock;
        // the Vec underneath is still fine (push is all-or-nothing).
        self.latencies_us.lock().unwrap_or_else(|e| e.into_inner()).push(latency.as_micros() as u64);
    }

    /// A request aged past its deadline before a worker reached it.
    pub fn record_timeout(&self) {
        self.timeouts.fetch_add(1, Ordering::Relaxed);
    }

    /// A request bounced off the full admission queue.
    pub fn record_rejection(&self) {
        self.rejections.fetch_add(1, Ordering::Relaxed);
    }

    /// A panic was caught while serving one request (or the batcher
    /// itself was respawned after one).
    pub fn record_worker_panic(&self) {
        self.worker_panics.fetch_add(1, Ordering::Relaxed);
    }

    /// A request was accepted into the admission queue. Updates the
    /// queue-depth high-water mark.
    pub fn record_enqueue(&self) {
        let depth = self.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.queue_hwm.fetch_max(depth, Ordering::Relaxed);
    }

    /// The batcher pulled a request off the admission queue.
    pub fn record_dequeue(&self) {
        // Saturating: a respawned batcher may drain requests enqueued
        // before a mid-batch panic reset its view of the world.
        let _ = self.queue_depth.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
            Some(d.saturating_sub(1))
        });
    }

    pub fn queries(&self) -> u64 {
        self.queries.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn timeouts(&self) -> u64 {
        self.timeouts.load(Ordering::Relaxed)
    }

    pub fn rejections(&self) -> u64 {
        self.rejections.load(Ordering::Relaxed)
    }

    pub fn worker_panics(&self) -> u64 {
        self.worker_panics.load(Ordering::Relaxed)
    }

    /// Deepest the admission queue ever got (0 when nothing ever waited).
    pub fn queue_depth_hwm(&self) -> u64 {
        self.queue_hwm.load(Ordering::Relaxed)
    }

    pub fn pjrt_fraction(&self) -> f64 {
        let q = self.queries().max(1);
        self.pjrt_queries.load(Ordering::Relaxed) as f64 / q as f64
    }

    pub fn mean_batch_fill(&self) -> f64 {
        let b = self.batches().max(1);
        self.batch_fill.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Latency percentile in microseconds (p in [0, 100]).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let mut v = self.latencies_us.lock().unwrap_or_else(|e| e.into_inner()).clone();
        if v.is_empty() {
            return 0;
        }
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn summary(&self) -> String {
        format!(
            "queries={} batches={} mean_fill={:.1} pjrt={:.0}% p50={}us p95={}us p99={}us \
             timeouts={} rejections={} worker_panics={} queue_hwm={}",
            self.queries(),
            self.batches(),
            self.mean_batch_fill(),
            100.0 * self.pjrt_fraction(),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(95.0),
            self.latency_percentile_us(99.0),
            self.timeouts(),
            self.rejections(),
            self.worker_panics(),
            self.queue_depth_hwm(),
        )
    }

    /// Machine-readable view of [`Metrics::summary`] — the same counters
    /// as one JSON object, so `zann serve --metrics-json` and the serve
    /// bench emit a contract instead of making CI scrape the summary line.
    pub fn metrics_json(&self) -> String {
        format!(
            "{{\"queries\": {}, \"batches\": {}, \"mean_batch_fill\": {:.3}, \
             \"pjrt_fraction\": {:.6}, \"p50_us\": {}, \"p95_us\": {}, \"p99_us\": {}, \
             \"timeouts\": {}, \"rejections\": {}, \"worker_panics\": {}, \"queue_hwm\": {}}}",
            self.queries(),
            self.batches(),
            self.mean_batch_fill(),
            self.pjrt_fraction(),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(95.0),
            self.latency_percentile_us(99.0),
            self.timeouts(),
            self.rejections(),
            self.worker_panics(),
            self.queue_depth_hwm(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_counters() {
        let m = Metrics::default();
        for i in 1..=100u64 {
            m.record_query(Duration::from_micros(i), i % 2 == 0);
        }
        m.record_batch(10);
        assert_eq!(m.queries(), 100);
        assert_eq!(m.batches(), 1);
        assert!((m.pjrt_fraction() - 0.5).abs() < 1e-9);
        assert_eq!(m.mean_batch_fill(), 10.0);
        let p50 = m.latency_percentile_us(50.0);
        assert!((49..=51).contains(&p50), "p50={p50}");
        assert_eq!(m.latency_percentile_us(100.0), 100);
        assert!(m.summary().contains("queries=100"));
    }

    #[test]
    fn degradation_counters() {
        let m = Metrics::default();
        m.record_timeout();
        m.record_timeout();
        m.record_rejection();
        m.record_worker_panic();
        assert_eq!(m.timeouts(), 2);
        assert_eq!(m.rejections(), 1);
        assert_eq!(m.worker_panics(), 1);
        let s = m.summary();
        assert!(s.contains("timeouts=2") && s.contains("rejections=1"), "{s}");
        assert!(s.contains("worker_panics=1"), "{s}");
    }

    #[test]
    fn empty_metrics() {
        let m = Metrics::default();
        assert_eq!(m.latency_percentile_us(99.0), 0);
        assert_eq!(m.pjrt_fraction(), 0.0);
        assert_eq!(m.queue_depth_hwm(), 0);
    }

    #[test]
    fn queue_hwm_tracks_peak_not_current_depth() {
        let m = Metrics::default();
        m.record_enqueue();
        m.record_enqueue();
        m.record_enqueue();
        m.record_dequeue();
        m.record_dequeue();
        assert_eq!(m.queue_depth_hwm(), 3, "hwm is the peak, not the current depth");
        m.record_enqueue();
        assert_eq!(m.queue_depth_hwm(), 3, "re-filling below the peak leaves the hwm");
        // Saturation: extra dequeues (batcher respawn) never underflow.
        for _ in 0..10 {
            m.record_dequeue();
        }
        m.record_enqueue();
        assert_eq!(m.queue_depth_hwm(), 3);
        assert!(m.summary().contains("queue_hwm=3"));
    }

    #[test]
    fn metrics_json_is_wellformed_and_complete() {
        let m = Metrics::default();
        m.record_query(Duration::from_micros(120), false);
        m.record_batch(1);
        m.record_timeout();
        m.record_rejection();
        m.record_enqueue();
        let j = m.metrics_json();
        for key in [
            "\"queries\"",
            "\"batches\"",
            "\"mean_batch_fill\"",
            "\"pjrt_fraction\"",
            "\"p50_us\"",
            "\"p95_us\"",
            "\"p99_us\"",
            "\"timeouts\"",
            "\"rejections\"",
            "\"worker_panics\"",
            "\"queue_hwm\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert!(j.contains("\"rejections\": 1") && j.contains("\"queue_hwm\": 1"), "{j}");
    }
}
