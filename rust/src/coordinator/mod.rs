//! L3 serving coordinator: request router, dynamic batcher, worker pool —
//! generic over any [`AnnIndex`] backend.
//!
//! Topology (std threads + channels; the offline vendor set has no tokio):
//!
//! ```text
//!   clients ──(mpsc)──▶ batcher ──▶ engine thread (PJRT coarse scoring)
//!                          │                │
//!                          └──▶ worker pool ◀┘   (scan + id resolution)
//!                                   │
//!                            reply channels
//! ```
//!
//! The batcher accumulates queries up to the artifact batch size (or a
//! wait deadline). Backends that expose a coarse stage
//! ([`AnnIndex::coarse_info`] — IVF) get one PJRT call for the whole
//! batch — the L2/L1 compute — and the per-query coarse rows fan out to
//! scan workers through [`AnnIndex::search_with_coarse_into`]. Backends
//! without one (graphs) skip the coarse hop and are served query-at-a-time
//! by the same worker pool, so batching, metrics and reply plumbing are
//! one code path for every index family.

pub mod metrics;

use crate::api::{AnnIndex, AnnScratch, QueryParams};
use crate::runtime::EngineHandle;
use crate::util::pool::default_threads;
use anyhow::Result;
use metrics::Metrics;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// One search request: query vector + reply channel.
pub struct Request {
    pub query: Vec<f32>,
    pub reply: mpsc::Sender<Response>,
    pub submitted: Instant,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub results: Vec<(f32, u32)>,
    pub latency: Duration,
    /// Whether the coarse stage ran on the PJRT executable.
    pub via_pjrt: bool,
}

pub struct ServeConfig {
    /// Batch size — must match an artifact batch for the PJRT path.
    pub batch_size: usize,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// Backend-generic search parameters (IVF reads `nprobe`, graphs
    /// read `ef`).
    pub search: QueryParams,
    pub scan_threads: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            batch_size: 64,
            max_wait: Duration::from_millis(2),
            search: QueryParams::default(),
            scan_threads: default_threads(),
        }
    }
}

/// Handle used by clients to submit queries.
#[derive(Clone)]
pub struct CoordinatorClient {
    tx: mpsc::Sender<Request>,
}

impl CoordinatorClient {
    /// Blocking search round-trip.
    pub fn search(&self, query: Vec<f32>) -> Result<Response> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request { query, reply, submitted: Instant::now() })
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("coordinator dropped reply"))
    }

    /// Fire-and-collect a whole batch (examples / benches).
    pub fn search_many(&self, queries: Vec<Vec<f32>>) -> Result<Vec<Response>> {
        let mut rxs = Vec::with_capacity(queries.len());
        for q in queries {
            let (reply, rx) = mpsc::channel();
            self.tx
                .send(Request { query: q, reply, submitted: Instant::now() })
                .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
            rxs.push(rx);
        }
        rxs.into_iter()
            .map(|rx| rx.recv().map_err(|_| anyhow::anyhow!("reply dropped")))
            .collect()
    }
}

pub struct Coordinator {
    pub client: CoordinatorClient,
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    batcher: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start serving `index` — any backend behind the [`AnnIndex`] trait
    /// (a concrete `Arc<IvfIndex>` / `Arc<GraphIndex>` coerces at the
    /// call site). `engine` may be `None` (pure-rust coarse); it is only
    /// consulted for backends that expose a coarse stage.
    pub fn start(
        index: Arc<dyn AnnIndex>,
        engine: Option<EngineHandle>,
        cfg: ServeConfig,
    ) -> Coordinator {
        let (tx, rx) = mpsc::channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        let stop = Arc::new(AtomicBool::new(false));
        let m = metrics.clone();
        let s = stop.clone();
        let batcher = std::thread::Builder::new()
            .name("zann-batcher".into())
            .spawn(move || batcher_loop(rx, index, engine, cfg, m, s))
            .expect("spawn batcher");
        Coordinator { client: CoordinatorClient { tx }, metrics, stop, batcher: Some(batcher) }
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Drop the implicit sender by taking the thread handle and joining.
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

fn batcher_loop(
    rx: mpsc::Receiver<Request>,
    index: Arc<dyn AnnIndex>,
    engine: Option<EngineHandle>,
    cfg: ServeConfig,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    let dim = index.dim();
    let b = cfg.batch_size;
    // Coarse-stage description, copied out once: backends without one
    // (graphs) run the direct per-query path below.
    let coarse_stage: Option<(Arc<Vec<f32>>, Vec<f32>, usize)> = index
        .coarse_info()
        .map(|ci| (Arc::new(ci.centroids.to_vec()), ci.norms.to_vec(), ci.k));
    let k = coarse_stage.as_ref().map(|(_, _, k)| *k).unwrap_or(0);
    let scratches: Vec<Mutex<AnnScratch>> =
        (0..cfg.scan_threads.max(1)).map(|_| Mutex::new(AnnScratch::default())).collect();
    let mut batch: Vec<Request> = Vec::with_capacity(b);
    // One padded query matrix and one fallback output, reused every batch.
    let mut flat = vec![0f32; b * dim];
    let mut coarse_buf: Vec<f32> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        // Block for the first request (with timeout so `stop` is seen).
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(r) => batch.push(r),
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        }
        // Fill up to batch_size or deadline.
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.batch_size {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }
        metrics.record_batch(batch.len());

        // Coarse scoring for the whole batch, padded to batch_size so the
        // fixed-shape PJRT executable applies. `flat` is filled in place
        // and passed by reference everywhere — the engine-error path
        // reuses the same buffer instead of rebuilding the matrix.
        if coarse_stage.is_some() {
            for (i, r) in batch.iter().enumerate() {
                flat[i * dim..(i + 1) * dim].copy_from_slice(&r.query);
            }
            flat[batch.len() * dim..].fill(0.0); // clear stale padding rows
        }
        let engine_out = match (&engine, &coarse_stage) {
            (Some(h), Some((centroids, _, k))) => {
                h.coarse(&flat, b, dim, centroids.clone(), *k).ok()
            }
            _ => None,
        };
        let (coarse, via_pjrt): (Option<&[f32]>, bool) = match (&coarse_stage, &engine_out) {
            (None, _) => (None, false),
            (Some(_), Some((v, via))) => (Some(v.as_slice()), *via),
            (Some((centroids, norms, _)), None) => {
                // Engine absent or errored: fused fallback, parallel over
                // the batch, into the reusable output buffer. Centroids
                // and norms come straight from the index — one source of
                // truth, and bit-identical to the backend's own coarse
                // stage.
                crate::runtime::coarse_fallback_into(
                    &flat,
                    b,
                    dim,
                    centroids,
                    norms,
                    cfg.scan_threads,
                    &mut coarse_buf,
                );
                (Some(coarse_buf.as_slice()), false)
            }
        };

        // Fan out scans to the worker pool.
        let nb = batch.len();
        let reqs: Vec<Request> = batch.drain(..).collect();
        let index_ref = &*index;
        let sp = &cfg.search;
        let scratches_ref = &scratches;
        let metrics_ref = &metrics;
        crate::util::pool::parallel_chunks(nb, cfg.scan_threads, |t, range| {
            let mut scratch = scratches_ref[t % scratches_ref.len()].lock().unwrap();
            for i in range {
                let r = &reqs[i];
                let mut results = Vec::with_capacity(sp.k);
                match coarse {
                    Some(c) => index_ref.search_with_coarse_into(
                        &r.query,
                        &c[i * k..(i + 1) * k],
                        sp,
                        &mut scratch,
                        &mut results,
                    ),
                    None => index_ref.search_into(&r.query, sp, &mut scratch, &mut results),
                }
                let latency = r.submitted.elapsed();
                metrics_ref.record_query(latency, via_pjrt);
                let _ = r.reply.send(Response { results, latency, via_pjrt });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{generate, groundtruth, Kind};
    use crate::index::{IvfBuildParams, IvfIndex, SearchParams, SearchScratch};

    #[test]
    fn serves_correct_results_without_engine() {
        let ds = generate(Kind::DeepLike, 2000, 40, 16, 21);
        let idx = Arc::new(IvfIndex::build(
            &ds.data,
            ds.dim,
            &IvfBuildParams { k: 32, id_codec: "roc".into(), threads: 2, ..Default::default() },
        ));
        let cfg = ServeConfig {
            batch_size: 8,
            max_wait: Duration::from_millis(1),
            search: QueryParams { nprobe: 8, k: 10, ..Default::default() },
            scan_threads: 2,
        };
        let coord = Coordinator::start(idx.clone(), None, cfg);
        // Compare against direct index search.
        let sp = SearchParams { nprobe: 8, k: 10 };
        let mut scratch = SearchScratch::default();
        let queries: Vec<Vec<f32>> = (0..ds.nq).map(|qi| ds.query(qi).to_vec()).collect();
        let responses = coord.client.search_many(queries).unwrap();
        for (qi, resp) in responses.iter().enumerate() {
            let want = idx.search(ds.query(qi), &sp, &mut scratch);
            assert_eq!(resp.results, want, "query {qi}");
            assert!(!resp.via_pjrt);
        }
        // Recall sanity end-to-end.
        let gt = groundtruth::exact_knn(&ds.data, &ds.queries, ds.dim, 10, 2);
        let res: Vec<Vec<u32>> = responses
            .iter()
            .map(|r| r.results.iter().map(|&(_, id)| id).collect())
            .collect();
        assert!(groundtruth::nn_recall_at_k(&gt, 10, &res, 10) > 0.8);
        assert!(coord.metrics.queries() >= 40);
        coord.stop();
    }

    #[test]
    fn batcher_groups_requests() {
        let ds = generate(Kind::DeepLike, 500, 30, 8, 22);
        let idx = Arc::new(IvfIndex::build(
            &ds.data,
            ds.dim,
            &IvfBuildParams { k: 8, id_codec: "compact".into(), threads: 1, ..Default::default() },
        ));
        let cfg = ServeConfig {
            batch_size: 16,
            max_wait: Duration::from_millis(20),
            search: QueryParams { nprobe: 4, k: 5, ..Default::default() },
            scan_threads: 2,
        };
        let coord = Coordinator::start(idx, None, cfg);
        let queries: Vec<Vec<f32>> = (0..30).map(|qi| ds.query(qi).to_vec()).collect();
        let _ = coord.client.search_many(queries).unwrap();
        // 30 requests in ≤ a handful of batches (not 30 singletons).
        assert!(coord.metrics.batches() <= 6, "batches={}", coord.metrics.batches());
        coord.stop();
    }

    #[test]
    fn serves_graph_backend_through_the_same_path() {
        use crate::api::GraphIndex;
        use crate::graph::nsg::{Nsg, NsgParams};
        let ds = generate(Kind::DeepLike, 1000, 20, 8, 23);
        let nsg = Nsg::build(
            &ds.data,
            ds.dim,
            &NsgParams { r: 16, knn_k: 24, threads: 2, seed: 3, ..Default::default() },
        );
        let gi = Arc::new(GraphIndex::from_nsg(&nsg, &ds.data, "ef").unwrap());
        let cfg = ServeConfig {
            batch_size: 8,
            max_wait: Duration::from_millis(1),
            search: QueryParams { k: 5, ef: 32, nprobe: 0 },
            scan_threads: 2,
        };
        let coord = Coordinator::start(gi.clone(), None, cfg);
        let queries: Vec<Vec<f32>> = (0..ds.nq).map(|qi| ds.query(qi).to_vec()).collect();
        let responses = coord.client.search_many(queries).unwrap();
        let p = QueryParams { k: 5, ef: 32, nprobe: 0 };
        let mut scratch = AnnScratch::default();
        let mut want = Vec::new();
        for (qi, resp) in responses.iter().enumerate() {
            gi.search_into(ds.query(qi), &p, &mut scratch, &mut want);
            assert_eq!(resp.results, want, "query {qi}");
            assert!(!resp.via_pjrt, "graphs have no PJRT coarse stage");
        }
        coord.stop();
    }
}
