//! L3 serving coordinator: request router, dynamic batcher, worker pool —
//! generic over any [`AnnIndex`] backend.
//!
//! Topology (std threads + channels; the offline vendor set has no tokio):
//!
//! ```text
//!   clients ──(bounded mpsc)──▶ batcher ──▶ engine thread (PJRT coarse scoring)
//!                                  │                │
//!                                  └──▶ worker pool ◀┘   (scan + id resolution)
//!                                           │
//!                                    reply channels
//! ```
//!
//! The batcher accumulates queries up to the artifact batch size (or a
//! wait deadline). Backends that expose a coarse stage
//! ([`AnnIndex::coarse_info`] — IVF) get one PJRT call for the whole
//! batch — the L2/L1 compute — and the per-query coarse rows fan out to
//! scan workers through [`AnnIndex::search_with_coarse_into`]. Backends
//! without one (graphs) skip the coarse hop and are served query-at-a-time
//! by the same worker pool, so batching, metrics and reply plumbing are
//! one code path for every index family.
//!
//! Degradation is structured, never silent: the admission queue is
//! bounded (a full queue yields [`ResponseStatus::Overloaded`], not
//! unbounded memory growth), requests that age past the configured
//! deadline are answered [`ResponseStatus::Timeout`] instead of occupying
//! a worker, and a panic while serving one request is caught, counted and
//! answered [`ResponseStatus::Failed`] — the pool keeps serving everyone
//! else. The [`metrics::Metrics`] counters (`timeouts`, `rejections`,
//! `worker_panics`) make every degraded path observable.

pub mod metrics;

use crate::api::{AnnIndex, AnnScratch, QueryParams};
use crate::obs::trace::{self, Stage};
use crate::runtime::EngineHandle;
use crate::util::pool::default_threads;
use anyhow::Result;
use metrics::Metrics;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// One search request: query vector + reply channel.
pub struct Request {
    pub query: Vec<f32>,
    pub reply: mpsc::Sender<Response>,
    pub submitted: Instant,
}

/// How a request left the coordinator. Anything but `Ok` carries empty
/// `results`; callers gate on the status, not on result emptiness (an
/// `Ok` answer over a tiny index may legitimately be empty too).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResponseStatus {
    /// Served normally.
    Ok,
    /// Aged past [`ServeConfig::deadline`] before a worker reached it.
    Timeout,
    /// Bounced off the full admission queue without being enqueued.
    Overloaded,
    /// A panic was caught while serving this request; the pool survived.
    Failed,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub results: Vec<(f32, u32)>,
    pub latency: Duration,
    /// Whether the coarse stage ran on the PJRT executable.
    pub via_pjrt: bool,
    pub status: ResponseStatus,
}

impl Response {
    pub fn is_ok(&self) -> bool {
        self.status == ResponseStatus::Ok
    }

    fn degraded(status: ResponseStatus, latency: Duration) -> Response {
        Response { results: Vec::new(), latency, via_pjrt: false, status }
    }
}

pub struct ServeConfig {
    /// Batch size — must match an artifact batch for the PJRT path.
    pub batch_size: usize,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// Backend-generic search parameters (IVF reads `nprobe`, graphs
    /// read `ef`).
    pub search: QueryParams,
    pub scan_threads: usize,
    /// Admission-queue capacity: at most this many requests wait for the
    /// batcher; further submissions are answered `Overloaded` instead of
    /// growing an unbounded backlog.
    pub queue_depth: usize,
    /// Per-query deadline measured from submission. A request older than
    /// this when a worker picks it up is answered `Timeout` without
    /// searching. `None` disables the check.
    pub deadline: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            batch_size: 64,
            max_wait: Duration::from_millis(2),
            search: QueryParams::default(),
            scan_threads: default_threads(),
            queue_depth: 1024,
            deadline: None,
        }
    }
}

/// Handle used by clients to submit queries.
#[derive(Clone)]
pub struct CoordinatorClient {
    tx: mpsc::SyncSender<Request>,
    metrics: Arc<Metrics>,
}

/// Outcome of a non-blocking [`CoordinatorClient::submit`]: either the
/// request was queued (await the receiver) or it was answered on the
/// spot (a full admission queue ⇒ `Overloaded`). The scatter-gather
/// serve node submits to every shard first, then collects — no shard
/// blocks another's submission.
pub enum Submitted {
    Queued(mpsc::Receiver<Response>),
    Done(Response),
}

impl Submitted {
    /// Block until the response is available. A queued request whose
    /// coordinator died resolves to an error, never a hang.
    pub fn wait(self) -> Result<Response> {
        match self {
            Submitted::Queued(rx) => {
                rx.recv().map_err(|_| anyhow::anyhow!("coordinator dropped reply"))
            }
            Submitted::Done(r) => Ok(r),
        }
    }
}

impl CoordinatorClient {
    /// Non-blocking submission: enqueue the request and return without
    /// waiting for the answer. A full admission queue is a normal
    /// (`Overloaded`) response, not an error — errors mean the
    /// coordinator is gone.
    pub fn submit(&self, query: Vec<f32>) -> Result<Submitted> {
        let submitted = Instant::now();
        let (reply, rx) = mpsc::channel();
        match self.tx.try_send(Request { query, reply, submitted }) {
            Ok(()) => {
                self.metrics.record_enqueue();
                Ok(Submitted::Queued(rx))
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.record_rejection();
                Ok(Submitted::Done(Response::degraded(
                    ResponseStatus::Overloaded,
                    submitted.elapsed(),
                )))
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                Err(anyhow::anyhow!("coordinator stopped"))
            }
        }
    }

    /// Blocking search round-trip ([`CoordinatorClient::submit`] + wait).
    pub fn search(&self, query: Vec<f32>) -> Result<Response> {
        self.submit(query)?.wait()
    }

    /// Fire-and-collect a whole batch (examples / benches). Requests that
    /// bounce off the full queue come back `Overloaded` in their slot, so
    /// the output stays index-aligned with `queries`.
    pub fn search_many(&self, queries: Vec<Vec<f32>>) -> Result<Vec<Response>> {
        let pending: Result<Vec<Submitted>> =
            queries.into_iter().map(|q| self.submit(q)).collect();
        pending?.into_iter().map(Submitted::wait).collect()
    }
}

pub struct Coordinator {
    pub client: CoordinatorClient,
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    batcher: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start serving `index` — any backend behind the [`AnnIndex`] trait
    /// (a concrete `Arc<IvfIndex>` / `Arc<GraphIndex>` coerces at the
    /// call site). `engine` may be `None` (pure-rust coarse); it is only
    /// consulted for backends that expose a coarse stage.
    pub fn start(
        index: Arc<dyn AnnIndex>,
        engine: Option<EngineHandle>,
        cfg: ServeConfig,
    ) -> Coordinator {
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_depth.max(1));
        let metrics = Arc::new(Metrics::default());
        let stop = Arc::new(AtomicBool::new(false));
        let m = metrics.clone();
        let s = stop.clone();
        let batcher = std::thread::Builder::new()
            .name("zann-batcher".into())
            .spawn(move || {
                // Respawn-on-panic: per-request panics are caught inside
                // the fan-out, but if the batch pipeline itself unwinds
                // (engine call, coarse fallback), the queue and serving
                // loop come straight back. Requests mid-batch at the
                // panic are dropped; their clients see a closed reply
                // channel, not a hang.
                loop {
                    let run = catch_unwind(AssertUnwindSafe(|| {
                        batcher_loop(&rx, &index, &engine, &cfg, &m, &s)
                    }));
                    match run {
                        Ok(()) => return, // stop flag or all senders gone
                        Err(_) => m.record_worker_panic(),
                    }
                    if s.load(Ordering::SeqCst) {
                        return;
                    }
                }
            })
            .expect("spawn batcher");
        Coordinator {
            client: CoordinatorClient { tx, metrics: metrics.clone() },
            metrics,
            stop,
            batcher: Some(batcher),
        }
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Drop the implicit sender by taking the thread handle and joining.
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

fn batcher_loop(
    rx: &mpsc::Receiver<Request>,
    index: &Arc<dyn AnnIndex>,
    engine: &Option<EngineHandle>,
    cfg: &ServeConfig,
    metrics: &Arc<Metrics>,
    stop: &Arc<AtomicBool>,
) {
    let dim = index.dim();
    let b = cfg.batch_size;
    // Coarse-stage description, copied out once: backends without one
    // (graphs) run the direct per-query path below.
    let coarse_stage: Option<(Arc<Vec<f32>>, Vec<f32>, usize)> = index
        .coarse_info()
        .map(|ci| (Arc::new(ci.centroids.to_vec()), ci.norms.to_vec(), ci.k));
    let k = coarse_stage.as_ref().map(|(_, _, k)| *k).unwrap_or(0);
    let scratches: Vec<Mutex<AnnScratch>> =
        (0..cfg.scan_threads.max(1)).map(|_| Mutex::new(AnnScratch::default())).collect();
    let mut batch: Vec<Request> = Vec::with_capacity(b);
    // One padded query matrix and one fallback output, reused every batch.
    let mut flat = vec![0f32; b * dim];
    let mut coarse_buf: Vec<f32> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        // Block for the first request (with timeout so `stop` is seen).
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(r) => {
                metrics.record_dequeue();
                batch.push(r);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        }
        // Fill up to batch_size or deadline.
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.batch_size {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => {
                    metrics.record_dequeue();
                    batch.push(r);
                }
                Err(_) => break,
            }
        }
        metrics.record_batch(batch.len());
        // Tracer anchor: everything before this instant is queue wait.
        let batch_ready = Instant::now();

        // Coarse scoring for the whole batch, padded to batch_size so the
        // fixed-shape PJRT executable applies. `flat` is filled in place
        // and passed by reference everywhere — the engine-error path
        // reuses the same buffer instead of rebuilding the matrix.
        if coarse_stage.is_some() {
            for (i, r) in batch.iter().enumerate() {
                flat[i * dim..(i + 1) * dim].copy_from_slice(&r.query);
            }
            flat[batch.len() * dim..].fill(0.0); // clear stale padding rows
        }
        let engine_out = match (engine.as_ref(), &coarse_stage) {
            (Some(h), Some((centroids, _, k))) => {
                h.coarse(&flat, b, dim, centroids.clone(), *k).ok()
            }
            _ => None,
        };
        let (coarse, via_pjrt): (Option<&[f32]>, bool) = match (&coarse_stage, &engine_out) {
            (None, _) => (None, false),
            (Some(_), Some((v, via))) => (Some(v.as_slice()), *via),
            (Some((centroids, norms, _)), None) => {
                // Engine absent or errored: fused fallback, parallel over
                // the batch, into the reusable output buffer. Centroids
                // and norms come straight from the index — one source of
                // truth, and bit-identical to the backend's own coarse
                // stage.
                crate::runtime::coarse_fallback_into(
                    &flat,
                    b,
                    dim,
                    centroids,
                    norms,
                    cfg.scan_threads,
                    &mut coarse_buf,
                );
                (Some(coarse_buf.as_slice()), false)
            }
        };

        // The batch-wide coarse stage is amortised; sampled queries get
        // their per-query share (batch cost / batch size).
        let coarse_done = Instant::now();
        let coarse_share_ns = coarse_done.saturating_duration_since(batch_ready).as_nanos() as u64
            / batch.len().max(1) as u64;

        // Fan out scans to the worker pool.
        let nb = batch.len();
        let reqs: Vec<Request> = batch.drain(..).collect();
        let index_ref = &**index;
        let sp = &cfg.search;
        let scratches_ref = &scratches;
        let metrics_ref = &**metrics;
        let per_query_deadline = cfg.deadline;
        crate::util::pool::parallel_chunks(nb, cfg.scan_threads, |t, range| {
            // A caught panic below never unwinds past the guard, so the
            // lock cannot actually poison from this loop; recover anyway
            // in case another worker died in the pool machinery itself.
            let mut scratch =
                scratches_ref[t % scratches_ref.len()].lock().unwrap_or_else(|e| e.into_inner());
            for i in range {
                let r = &reqs[i];
                if let Some(dl) = per_query_deadline {
                    if r.submitted.elapsed() >= dl {
                        metrics_ref.record_timeout();
                        let _ = r.reply.send(Response::degraded(
                            ResponseStatus::Timeout,
                            r.submitted.elapsed(),
                        ));
                        continue;
                    }
                }
                // Sampled queries build their whole stage timeline on
                // this worker thread: wait-to-batch + wait-for-worker is
                // QueueWait, the amortised batch coarse stage is
                // CoarseQuantize, and the backend attributes decode/
                // scan/merge inside search. When unsampled (or obs off)
                // all of this short-circuits to nothing.
                let sampled = trace::begin_query();
                let mut search_start = None;
                let mut pre_ns = 0;
                if sampled {
                    let wait_ns = batch_ready.saturating_duration_since(r.submitted).as_nanos()
                        as u64
                        + coarse_done.elapsed().as_nanos() as u64;
                    trace::add_ns(Stage::QueueWait, wait_ns);
                    trace::add_ns(Stage::CoarseQuantize, coarse_share_ns);
                    pre_ns = trace::thread_ns();
                    search_start = Some(Instant::now());
                }
                let mut results = Vec::with_capacity(sp.k);
                let searched = catch_unwind(AssertUnwindSafe(|| match coarse {
                    Some(c) => index_ref.search_with_coarse_into(
                        &r.query,
                        &c[i * k..(i + 1) * k],
                        sp,
                        &mut scratch,
                        &mut results,
                    ),
                    None => index_ref.search_into(&r.query, sp, &mut scratch, &mut results),
                }));
                let latency = r.submitted.elapsed();
                if searched.is_err() {
                    trace::discard();
                    // The scratch may hold arbitrary mid-search state;
                    // replace it before the next request reuses it.
                    *scratch = AnnScratch::default();
                    metrics_ref.record_worker_panic();
                    let _ = r.reply.send(Response::degraded(ResponseStatus::Failed, latency));
                    continue;
                }
                if let Some(start) = search_start {
                    // Attribute search time the backend did not claim for
                    // a named stage to `Other`, so stage sums track e2e.
                    let inner = trace::thread_ns().saturating_sub(pre_ns);
                    let search_ns = start.elapsed().as_nanos() as u64;
                    trace::add_ns(Stage::Other, search_ns.saturating_sub(inner));
                }
                metrics_ref.record_query(latency, via_pjrt);
                let reply_start = if sampled { Some(Instant::now()) } else { None };
                let _ = r.reply.send(Response {
                    results,
                    latency,
                    via_pjrt,
                    status: ResponseStatus::Ok,
                });
                if let Some(start) = reply_start {
                    trace::add_ns(Stage::Reply, start.elapsed().as_nanos() as u64);
                    trace::end_query(r.submitted.elapsed());
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{CoarseInfo, IndexKind, IndexStats};
    use crate::datasets::{generate, groundtruth, Kind};
    use crate::index::{IvfBuildParams, IvfIndex, SearchParams, SearchScratch};

    #[test]
    fn serves_correct_results_without_engine() {
        let ds = generate(Kind::DeepLike, 2000, 40, 16, 21);
        let idx = Arc::new(IvfIndex::build(
            &ds.data,
            ds.dim,
            &IvfBuildParams { k: 32, id_codec: "roc".into(), threads: 2, ..Default::default() },
        ));
        let cfg = ServeConfig {
            batch_size: 8,
            max_wait: Duration::from_millis(1),
            search: QueryParams { nprobe: 8, k: 10, ..Default::default() },
            scan_threads: 2,
            ..Default::default()
        };
        let coord = Coordinator::start(idx.clone(), None, cfg);
        // Compare against direct index search.
        let sp = SearchParams { nprobe: 8, k: 10 };
        let mut scratch = SearchScratch::default();
        let queries: Vec<Vec<f32>> = (0..ds.nq).map(|qi| ds.query(qi).to_vec()).collect();
        let responses = coord.client.search_many(queries).unwrap();
        for (qi, resp) in responses.iter().enumerate() {
            let want = idx.search(ds.query(qi), &sp, &mut scratch);
            assert_eq!(resp.results, want, "query {qi}");
            assert!(!resp.via_pjrt);
            assert_eq!(resp.status, ResponseStatus::Ok);
        }
        // Recall sanity end-to-end.
        let gt = groundtruth::exact_knn(&ds.data, &ds.queries, ds.dim, 10, 2);
        let res: Vec<Vec<u32>> = responses
            .iter()
            .map(|r| r.results.iter().map(|&(_, id)| id).collect())
            .collect();
        assert!(groundtruth::nn_recall_at_k(&gt, 10, &res, 10) > 0.8);
        assert!(coord.metrics.queries() >= 40);
        assert_eq!(coord.metrics.timeouts(), 0);
        assert_eq!(coord.metrics.rejections(), 0);
        assert_eq!(coord.metrics.worker_panics(), 0);
        coord.stop();
    }

    #[test]
    fn batcher_groups_requests() {
        let ds = generate(Kind::DeepLike, 500, 30, 8, 22);
        let idx = Arc::new(IvfIndex::build(
            &ds.data,
            ds.dim,
            &IvfBuildParams { k: 8, id_codec: "compact".into(), threads: 1, ..Default::default() },
        ));
        let cfg = ServeConfig {
            batch_size: 16,
            max_wait: Duration::from_millis(20),
            search: QueryParams { nprobe: 4, k: 5, ..Default::default() },
            scan_threads: 2,
            ..Default::default()
        };
        let coord = Coordinator::start(idx, None, cfg);
        let queries: Vec<Vec<f32>> = (0..30).map(|qi| ds.query(qi).to_vec()).collect();
        let _ = coord.client.search_many(queries).unwrap();
        // 30 requests in ≤ a handful of batches (not 30 singletons).
        assert!(coord.metrics.batches() <= 6, "batches={}", coord.metrics.batches());
        coord.stop();
    }

    #[test]
    fn serves_graph_backend_through_the_same_path() {
        use crate::api::GraphIndex;
        use crate::graph::nsg::{Nsg, NsgParams};
        let ds = generate(Kind::DeepLike, 1000, 20, 8, 23);
        let nsg = Nsg::build(
            &ds.data,
            ds.dim,
            &NsgParams { r: 16, knn_k: 24, threads: 2, seed: 3, ..Default::default() },
        );
        let gi = Arc::new(GraphIndex::from_nsg(&nsg, &ds.data, "ef").unwrap());
        let cfg = ServeConfig {
            batch_size: 8,
            max_wait: Duration::from_millis(1),
            search: QueryParams { k: 5, ef: 32, nprobe: 0 },
            scan_threads: 2,
            ..Default::default()
        };
        let coord = Coordinator::start(gi.clone(), None, cfg);
        let queries: Vec<Vec<f32>> = (0..ds.nq).map(|qi| ds.query(qi).to_vec()).collect();
        let responses = coord.client.search_many(queries).unwrap();
        let p = QueryParams { k: 5, ef: 32, nprobe: 0 };
        let mut scratch = AnnScratch::default();
        let mut want = Vec::new();
        for (qi, resp) in responses.iter().enumerate() {
            gi.search_into(ds.query(qi), &p, &mut scratch, &mut want);
            assert_eq!(resp.results, want, "query {qi}");
            assert!(!resp.via_pjrt, "graphs have no PJRT coarse stage");
        }
        coord.stop();
    }

    /// Fault-injection wrapper: delegates to a real IVF index but can
    /// panic on demand (NaN query) or serve slowly. `coarse_info` is
    /// hidden so every request takes the direct per-query path, which is
    /// where the injected faults land.
    struct ChaosIndex {
        inner: Arc<IvfIndex>,
        sleep: Option<Duration>,
        panic_on_nan: bool,
    }

    impl AnnIndex for ChaosIndex {
        fn kind(&self) -> IndexKind {
            AnnIndex::kind(&*self.inner)
        }

        fn dim(&self) -> usize {
            AnnIndex::dim(&*self.inner)
        }

        fn len(&self) -> usize {
            AnnIndex::len(&*self.inner)
        }

        fn stats(&self) -> IndexStats {
            AnnIndex::stats(&*self.inner)
        }

        fn coarse_info(&self) -> Option<CoarseInfo<'_>> {
            None
        }

        fn search_into(
            &self,
            query: &[f32],
            params: &QueryParams,
            scratch: &mut AnnScratch,
            out: &mut Vec<(f32, u32)>,
        ) {
            if self.panic_on_nan && query[0].is_nan() {
                panic!("injected worker panic");
            }
            if let Some(d) = self.sleep {
                std::thread::sleep(d);
            }
            AnnIndex::search_into(&*self.inner, query, params, scratch, out);
        }

        fn to_bytes(&self) -> Result<Vec<u8>> {
            AnnIndex::to_bytes(&*self.inner)
        }
    }

    fn tiny_ivf() -> Arc<IvfIndex> {
        let ds = generate(Kind::DeepLike, 400, 4, 8, 24);
        Arc::new(IvfIndex::build(
            &ds.data,
            ds.dim,
            &IvfBuildParams { k: 8, id_codec: "roc".into(), threads: 1, ..Default::default() },
        ))
    }

    #[test]
    fn survives_injected_worker_panic_and_keeps_serving() {
        let inner = tiny_ivf();
        let chaos =
            Arc::new(ChaosIndex { inner: inner.clone(), sleep: None, panic_on_nan: true });
        let cfg = ServeConfig {
            batch_size: 4,
            max_wait: Duration::from_millis(1),
            search: QueryParams { nprobe: 4, k: 5, ..Default::default() },
            scan_threads: 2,
            ..Default::default()
        };
        let coord = Coordinator::start(chaos, None, cfg);
        let dim = inner.dim;
        let bad = coord.client.search(vec![f32::NAN; dim]).unwrap();
        assert_eq!(bad.status, ResponseStatus::Failed);
        assert!(bad.results.is_empty());
        // The pool is still alive and answers clean queries normally.
        let good = coord.client.search(vec![0.25; dim]).unwrap();
        assert_eq!(good.status, ResponseStatus::Ok);
        assert!(!good.results.is_empty());
        assert!(coord.metrics.worker_panics() >= 1);
        assert!(coord.metrics.summary().contains("worker_panics="));
        coord.stop();
    }

    #[test]
    fn per_query_deadline_yields_timeout_not_a_hang() {
        let inner = tiny_ivf();
        let dim = inner.dim;
        let chaos = Arc::new(ChaosIndex { inner, sleep: None, panic_on_nan: false });
        let cfg = ServeConfig {
            batch_size: 4,
            max_wait: Duration::from_millis(1),
            search: QueryParams { nprobe: 4, k: 5, ..Default::default() },
            scan_threads: 1,
            // Zero-length budget: every request is already late when a
            // worker reaches it — deterministic timeout.
            deadline: Some(Duration::ZERO),
            ..Default::default()
        };
        let coord = Coordinator::start(chaos, None, cfg);
        let resp = coord.client.search(vec![0.5; dim]).unwrap();
        assert_eq!(resp.status, ResponseStatus::Timeout);
        assert!(resp.results.is_empty());
        assert!(coord.metrics.timeouts() >= 1);
        coord.stop();
    }

    #[test]
    fn bounded_queue_rejects_overload_in_order() {
        let inner = tiny_ivf();
        let dim = inner.dim;
        // Each query holds a worker for 30ms, and only one request may
        // wait — the rest of the burst must bounce immediately.
        let chaos = Arc::new(ChaosIndex {
            inner,
            sleep: Some(Duration::from_millis(30)),
            panic_on_nan: false,
        });
        let cfg = ServeConfig {
            batch_size: 1,
            max_wait: Duration::from_millis(1),
            search: QueryParams { nprobe: 4, k: 5, ..Default::default() },
            scan_threads: 1,
            queue_depth: 1,
            ..Default::default()
        };
        let coord = Coordinator::start(chaos, None, cfg);
        let queries: Vec<Vec<f32>> = (0..8).map(|_| vec![0.5; dim]).collect();
        let responses = coord.client.search_many(queries).unwrap();
        assert_eq!(responses.len(), 8, "every request gets an answer, served or rejected");
        let served = responses.iter().filter(|r| r.is_ok()).count();
        let rejected =
            responses.iter().filter(|r| r.status == ResponseStatus::Overloaded).count();
        assert_eq!(served + rejected, 8);
        assert!(served >= 1, "the queue admits at least the first request");
        assert!(rejected >= 5, "a burst of 8 into depth-1 must mostly bounce, got {rejected}");
        assert!(coord.metrics.rejections() >= rejected as u64);
        assert!(coord.metrics.queue_depth_hwm() >= 1, "something waited in the queue");
        coord.stop();
    }

    #[test]
    fn dropped_reply_receivers_are_ignored() {
        let inner = tiny_ivf();
        let dim = inner.dim;
        let cfg = ServeConfig {
            batch_size: 4,
            max_wait: Duration::from_millis(1),
            search: QueryParams { nprobe: 4, k: 5, ..Default::default() },
            scan_threads: 1,
            ..Default::default()
        };
        let coord = Coordinator::start(inner, None, cfg);
        // A client that gave up: its reply receiver is gone before the
        // worker answers. The send must be ignored, not unwind the pool.
        let (reply, rx) = mpsc::channel();
        drop(rx);
        coord
            .client
            .tx
            .try_send(Request { query: vec![0.5; dim], reply, submitted: Instant::now() })
            .unwrap();
        let resp = coord.client.search(vec![0.5; dim]).unwrap();
        assert_eq!(resp.status, ResponseStatus::Ok);
        assert_eq!(coord.metrics.worker_panics(), 0);
        coord.stop();
    }
}
