//! Fenwick (binary indexed) tree with order statistics.
//!
//! This is the data structure the paper singles out as the cost of ROC
//! ("Most of the wall-time spent with ROC is due to the Fenwick Tree"): it
//! maintains the multiset of not-yet-encoded elements and answers
//! *select-kth* / *rank* in O(log n) during bits-back coding.  The `select`
//! here uses the classic power-of-two bit-descent, so no binary search over
//! prefix sums is needed.

/// Fenwick tree over `n` slots of u64 counts.
#[derive(Clone, Debug)]
pub struct Fenwick {
    tree: Vec<u64>,
    n: usize,
    total: u64,
    /// Largest power of two <= n (descent start).
    top: usize,
}

impl Fenwick {
    pub fn new(n: usize) -> Self {
        let top = if n == 0 { 0 } else { 1 << (usize::BITS - 1 - n.leading_zeros()) };
        Fenwick { tree: vec![0; n + 1], n, total: 0, top }
    }

    /// Build from initial counts in O(n).
    pub fn from_counts(counts: &[u64]) -> Self {
        let n = counts.len();
        let mut fw = Fenwick::new(n);
        for (i, &c) in counts.iter().enumerate() {
            fw.tree[i + 1] = fw.tree[i + 1].wrapping_add(c);
            let j = i + 1 + ((i + 1) & (i + 1).wrapping_neg());
            if j <= n {
                let v = fw.tree[i + 1];
                fw.tree[j] = fw.tree[j].wrapping_add(v);
            }
            fw.total += c;
        }
        fw
    }

    /// All-ones tree (each of the n slots has count 1).
    pub fn ones(n: usize) -> Self {
        Self::from_counts(&vec![1u64; n])
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Zero every slot in place, keeping the allocation (scratch reuse).
    pub fn clear(&mut self) {
        self.tree.fill(0);
        self.total = 0;
    }

    /// Reset to the all-ones configuration in place (the state
    /// [`Fenwick::ones`] builds), keeping the allocation. For unit counts
    /// the internal node `j` covers exactly `lowbit(j)` slots.
    pub fn reset_ones(&mut self) {
        self.tree[0] = 0;
        for j in 1..=self.n {
            self.tree[j] = (j & j.wrapping_neg()) as u64;
        }
        self.total = self.n as u64;
    }

    /// Add `delta` to slot `i` (delta may be negative).
    #[inline]
    pub fn add(&mut self, i: usize, delta: i64) {
        debug_assert!(i < self.n);
        self.total = self.total.wrapping_add(delta as u64);
        let mut j = i + 1;
        while j <= self.n {
            self.tree[j] = self.tree[j].wrapping_add(delta as u64);
            j += j & j.wrapping_neg();
        }
    }

    /// Sum of counts in `[0, i)`.
    #[inline]
    pub fn prefix_sum(&self, i: usize) -> u64 {
        debug_assert!(i <= self.n);
        let mut s = 0u64;
        let mut j = i;
        while j > 0 {
            s = s.wrapping_add(self.tree[j]);
            j &= j - 1;
        }
        s
    }

    /// Count at slot `i`.
    pub fn get(&self, i: usize) -> u64 {
        self.prefix_sum(i + 1) - self.prefix_sum(i)
    }

    /// Largest slot index `i` such that `prefix_sum(i) <= k`, together with
    /// `k - prefix_sum(i)` — i.e. the slot containing mass-offset `k` and
    /// the residual within it. Requires `k < total`.
    ///
    /// This is the ANS inverse-CDF lookup: `slot_of(slot_value)` maps an
    /// ANS slot to (symbol, offset-within-symbol).
    #[inline]
    pub fn slot_of(&self, k: u64) -> (usize, u64) {
        debug_assert!(k < self.total, "k={k} total={}", self.total);
        let mut pos = 0usize;
        let mut rem = k;
        let mut step = self.top;
        while step > 0 {
            let next = pos + step;
            if next <= self.n && self.tree[next] <= rem {
                rem -= self.tree[next];
                pos = next;
            }
            step >>= 1;
        }
        (pos, rem) // pos slots have cumulative <= k; slot index = pos
    }

    /// Index of the k-th *present* element when counts are 0/1 occupancy
    /// (select-kth-remaining, used by the ROC/REC position trackers).
    #[inline]
    pub fn select_kth(&self, k: u64) -> usize {
        self.slot_of(k).0
    }

    /// Like [`Fenwick::slot_of`] but every slot carries an extra additive
    /// weight `alpha` (effective count of slot i = count_i + alpha).
    ///
    /// This is the inverse CDF of a Pólya urn with a uniform pseudo-count
    /// prior — the vertex model of Random Edge Coding.  Requires
    /// `k < total + alpha * n`.
    #[inline]
    pub fn slot_of_with_linear(&self, k: u64, alpha: u64) -> (usize, u64) {
        debug_assert!(k < self.total + alpha * self.n as u64);
        let mut pos = 0usize;
        let mut rem = k;
        let mut step = self.top;
        while step > 0 {
            let next = pos + step;
            if next <= self.n {
                let block = self.tree[next] + alpha * step as u64;
                if block <= rem {
                    rem -= block;
                    pos = next;
                }
            }
            step >>= 1;
        }
        (pos, rem)
    }

    /// Prefix sum with the same additive per-slot weight as
    /// [`Fenwick::slot_of_with_linear`].
    #[inline]
    pub fn prefix_sum_with_linear(&self, i: usize, alpha: u64) -> u64 {
        self.prefix_sum(i) + alpha * i as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn prefix_sums_match_naive() {
        let mut rng = Rng::new(1);
        for &n in &[1usize, 2, 7, 64, 100, 1000] {
            let counts: Vec<u64> = (0..n).map(|_| rng.below(10)).collect();
            let fw = Fenwick::from_counts(&counts);
            let mut acc = 0;
            for i in 0..=n {
                assert_eq!(fw.prefix_sum(i), acc, "n={n} i={i}");
                if i < n {
                    assert_eq!(fw.get(i), counts[i]);
                    acc += counts[i];
                }
            }
            assert_eq!(fw.total(), acc);
        }
    }

    #[test]
    fn add_and_query_random() {
        let mut rng = Rng::new(2);
        let n = 500;
        let mut naive = vec![0i64; n];
        let mut fw = Fenwick::new(n);
        for _ in 0..5000 {
            let i = rng.below(n as u64) as usize;
            let d = rng.below(7) as i64 - 3;
            if naive[i] + d < 0 {
                continue;
            }
            naive[i] += d;
            fw.add(i, d);
        }
        let mut acc = 0u64;
        for i in 0..n {
            assert_eq!(fw.prefix_sum(i), acc);
            acc += naive[i] as u64;
        }
    }

    #[test]
    fn slot_of_is_inverse_cdf() {
        let counts = vec![3u64, 0, 5, 1, 0, 2];
        let fw = Fenwick::from_counts(&counts);
        let mut expect = Vec::new();
        for (i, &c) in counts.iter().enumerate() {
            for off in 0..c {
                expect.push((i, off));
            }
        }
        for (k, &(i, off)) in expect.iter().enumerate() {
            assert_eq!(fw.slot_of(k as u64), (i, off), "k={k}");
        }
    }

    #[test]
    fn slot_of_random_property() {
        let mut rng = Rng::new(3);
        for &n in &[1usize, 3, 64, 65, 513, 1000] {
            let counts: Vec<u64> = (0..n).map(|_| rng.below(5)).collect();
            let fw = Fenwick::from_counts(&counts);
            if fw.total() == 0 {
                continue;
            }
            for _ in 0..200 {
                let k = rng.below(fw.total());
                let (i, off) = fw.slot_of(k);
                assert!(fw.prefix_sum(i) <= k);
                assert_eq!(fw.prefix_sum(i) + off, k);
                assert!(k < fw.prefix_sum(i + 1));
            }
        }
    }

    #[test]
    fn slot_of_with_linear_matches_naive() {
        let mut rng = Rng::new(7);
        for &n in &[1usize, 5, 64, 200, 1000] {
            for &alpha in &[1u64, 3] {
                let counts: Vec<u64> = (0..n).map(|_| rng.below(4)).collect();
                let fw = Fenwick::from_counts(&counts);
                let total = fw.total() + alpha * n as u64;
                // Naive expansion of the weighted CDF.
                let mut expect = Vec::new();
                for (i, &c) in counts.iter().enumerate() {
                    for off in 0..(c + alpha) {
                        expect.push((i, off));
                    }
                }
                assert_eq!(expect.len() as u64, total);
                for _ in 0..300 {
                    let k = rng.below(total);
                    let (i, off) = fw.slot_of_with_linear(k, alpha);
                    assert_eq!((i, off), expect[k as usize], "n={n} k={k}");
                    assert_eq!(
                        fw.prefix_sum_with_linear(i, alpha) + off,
                        k
                    );
                }
            }
        }
    }

    #[test]
    fn clear_and_reset_ones_match_fresh() {
        let mut rng = Rng::new(8);
        for &n in &[1usize, 2, 7, 64, 100, 513] {
            let mut fw = Fenwick::ones(n);
            // Mutate arbitrarily.
            for _ in 0..50 {
                let i = rng.below(n as u64) as usize;
                fw.add(i, rng.below(5) as i64);
            }
            fw.reset_ones();
            let fresh = Fenwick::ones(n);
            for i in 0..=n {
                assert_eq!(fw.prefix_sum(i), fresh.prefix_sum(i), "n={n} i={i}");
            }
            assert_eq!(fw.total(), n as u64);
            fw.clear();
            for i in 0..=n {
                assert_eq!(fw.prefix_sum(i), 0);
            }
            assert_eq!(fw.total(), 0);
        }
    }

    #[test]
    fn select_kth_remaining_simulation() {
        // Occupancy use-case: remove elements one by one, as ROC does.
        let mut rng = Rng::new(4);
        let n = 300;
        let mut fw = Fenwick::ones(n);
        let mut alive: Vec<usize> = (0..n).collect();
        while !alive.is_empty() {
            let k = rng.below(alive.len() as u64);
            let idx = fw.select_kth(k);
            assert_eq!(idx, alive[k as usize]);
            fw.add(idx, -1);
            alive.remove(k as usize);
        }
        assert_eq!(fw.total(), 0);
    }
}
