//! # zann — lossless ID compression for approximate nearest-neighbor search
//!
//! A reproduction of *"Lossless Compression of Vector IDs for Approximate
//! Nearest Neighbor Search"* (Severo, Ottaviano, Muckley, Ullrich, Douze, 2025)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the ANN serving system: IVF and graph
//!   (NSG/HNSW) indexes whose vector-id payloads are stored through pluggable
//!   lossless codecs ([`codecs`]), a mutable LSM-style IVF ([`dynamic`])
//!   that keeps those payloads compressed under live inserts/deletes, a
//!   batching query coordinator ([`coordinator`]), runtime-dispatched
//!   SIMD scan kernels ([`simd`]: AVX2/SSE4.1 with a bit-identical
//!   scalar reference) and the PJRT runtime ([`runtime`]) that executes
//!   the AOT-compiled distance kernels.
//! * **Layer 2 (python/compile/model.py)** — JAX compute graphs for coarse
//!   quantizer assignment and PQ look-up-table construction, lowered once to
//!   HLO text in `artifacts/`.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for blocked
//!   pairwise squared-L2 distance and PQ LUTs, validated against a pure-jnp
//!   oracle and lowered (interpret mode) into the same HLO.
//!
//! The paper's contribution — entropy coding of the *sets* of vector ids that
//! IVF inverted lists and graph adjacency lists are made of — lives in
//! [`codecs`]: asymmetric-numeral-system bits-back coders (ROC for sets, REC
//! for whole graphs), Elias-Fano, wavelet trees (flat and RRR-compressed) and
//! a Zuckerli-style reference baseline.
//!
//! # Example: compress one inverted list losslessly
//!
//! Codecs are looked up through the [`codecs::CodecSpec`] registry
//! (fallible, with the valid-name list in the error) and treat the list
//! as a *set* — decode may return the ids in a different (deterministic)
//! order, which is exactly the invariance ROC monetizes:
//!
//! ```
//! use zann::codecs::CodecSpec;
//!
//! let codec = CodecSpec::parse("roc").unwrap().id_codec().unwrap();
//! let ids: Vec<u32> = vec![3, 14, 15, 92, 65];
//! let enc = codec.encode(&ids, 100); // ids drawn from [0, 100)
//!
//! let mut out = Vec::new();
//! codec.decode(&enc.bytes, 100, ids.len(), &mut out);
//! out.sort_unstable();
//! assert_eq!(out, vec![3, 14, 15, 65, 92]);
//! assert!(enc.bits as usize <= enc.bytes.len() * 8);
//! assert!(CodecSpec::parse("rocc").is_err(), "typos are reported, not ignored");
//! ```
//!
//! # Example: an IVF index with compressed ids
//!
//! Lossless id compression leaves search results untouched; only
//! [`index::IvfIndex::bits_per_id`] changes across codecs:
//!
//! ```
//! use zann::datasets::{generate, Kind};
//! use zann::index::{IvfBuildParams, IvfIndex, SearchParams, SearchScratch};
//!
//! let ds = generate(Kind::DeepLike, 2000, 4, 8, 7);
//! let idx = IvfIndex::build(
//!     &ds.data,
//!     ds.dim,
//!     &IvfBuildParams { k: 16, id_codec: "roc".into(), threads: 2, ..Default::default() },
//! );
//! assert!(idx.bits_per_id() < 64.0);
//!
//! let mut scratch = SearchScratch::default();
//! let hits = idx.search(ds.query(0), &SearchParams { nprobe: 4, k: 5 }, &mut scratch);
//! assert_eq!(hits.len(), 5);
//! ```
//!
//! # Example: save, reopen and serve through the unified API
//!
//! Every backend implements [`api::AnnIndex`]; the container format
//! ([`api::persist`]) stores the compressed streams verbatim, so a
//! reopened index returns bit-identical results without re-encoding
//! anything:
//!
//! ```
//! use zann::api::{persist, AnnIndex, AnnScratch, QueryParams};
//! use zann::datasets::{generate, Kind};
//! use zann::index::{IvfBuildParams, IvfIndex};
//!
//! let ds = generate(Kind::DeepLike, 2000, 4, 8, 7);
//! let idx = IvfIndex::build(
//!     &ds.data,
//!     ds.dim,
//!     &IvfBuildParams { k: 16, id_codec: "roc".into(), threads: 2, ..Default::default() },
//! );
//! let bytes = idx.to_bytes().unwrap();          // compressed blobs, verbatim
//! let back = persist::open_bytes(bytes).unwrap(); // Box<dyn AnnIndex>, zero transcode
//!
//! let p = QueryParams { k: 5, nprobe: 4, ..Default::default() };
//! let (mut s1, mut s2) = (AnnScratch::default(), AnnScratch::default());
//! let (mut a, mut b) = (Vec::new(), Vec::new());
//! AnnIndex::search_into(&idx, ds.query(0), &p, &mut s1, &mut a);
//! back.search_into(ds.query(0), &p, &mut s2, &mut b);
//! assert_eq!(a, b, "reopened index is bit-identical");
//! ```

pub mod util;
pub mod obs;
pub mod bitvec;
pub mod ans;
pub mod fenwick;
pub mod codecs;
pub mod simd;
pub mod quant;
pub mod datasets;
pub mod index;
pub mod dynamic;
pub mod graph;
pub mod runtime;
pub mod api;
pub mod durable;
pub mod coordinator;
pub mod serve;
pub mod eval;
