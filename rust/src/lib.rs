//! # zann — lossless ID compression for approximate nearest-neighbor search
//!
//! A reproduction of *"Lossless Compression of Vector IDs for Approximate
//! Nearest Neighbor Search"* (Severo, Ottaviano, Muckley, Ullrich, Douze, 2025)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the ANN serving system: IVF and graph
//!   (NSG/HNSW) indexes whose vector-id payloads are stored through pluggable
//!   lossless codecs ([`codecs`]), a batching query coordinator
//!   ([`coordinator`]) and the PJRT runtime ([`runtime`]) that executes the
//!   AOT-compiled distance kernels.
//! * **Layer 2 (python/compile/model.py)** — JAX compute graphs for coarse
//!   quantizer assignment and PQ look-up-table construction, lowered once to
//!   HLO text in `artifacts/`.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for blocked
//!   pairwise squared-L2 distance and PQ LUTs, validated against a pure-jnp
//!   oracle and lowered (interpret mode) into the same HLO.
//!
//! The paper's contribution — entropy coding of the *sets* of vector ids that
//! IVF inverted lists and graph adjacency lists are made of — lives in
//! [`codecs`]: asymmetric-numeral-system bits-back coders (ROC for sets, REC
//! for whole graphs), Elias-Fano, wavelet trees (flat and RRR-compressed) and
//! a Zuckerli-style reference baseline.

pub mod util;
pub mod bitvec;
pub mod ans;
pub mod fenwick;
pub mod codecs;
pub mod quant;
pub mod datasets;
pub mod index;
pub mod graph;
pub mod runtime;
pub mod coordinator;
pub mod eval;
