//! Capture the compiler version at build time so `bench-recall` can stamp
//! its environment manifest (`BENCH_recall.json` is only comparable
//! across runs when the toolchain is recorded next to the numbers).

use std::process::Command;

fn main() {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".into());
    let version = Command::new(&rustc)
        .arg("--version")
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .unwrap_or_else(|| "unknown".into());
    println!("cargo:rustc-env=ZANN_RUSTC_VERSION={version}");
    println!("cargo:rerun-if-changed=build.rs");
}
