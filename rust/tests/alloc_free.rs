//! Steady-state allocation accounting for the search hot path.
//!
//! The acceptance contract of the allocation-free refactor: with a warmed
//! `SearchScratch` and a reused result buffer, `IvfIndex::search_into`
//! performs **zero** heap allocations per query for the random-access id
//! stores (`unc64`, `compact`, `ef`) and zero per probed cluster beyond
//! first-touch scratch growth for the per-cluster decoders (`roc`,
//! PQ-compressed codes). Asserted with a counting global allocator: run
//! the full query set twice to settle every scratch buffer at its
//! steady-state size, then require the third pass to allocate nothing.
//!
//! (Integration test on purpose: each integration test binary may install
//! its own `#[global_allocator]` without affecting the rest of the suite.)

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use zann::datasets::{generate, Dataset, Kind};
use zann::index::{IvfBuildParams, IvfIndex, SearchParams, SearchScratch, VectorMode};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocation_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

fn run_all_queries(
    idx: &IvfIndex,
    ds: &Dataset,
    sp: &SearchParams,
    scratch: &mut SearchScratch,
    out: &mut Vec<(f32, u32)>,
) -> usize {
    let mut total = 0usize;
    for qi in 0..ds.nq {
        idx.search_into(ds.query(qi), sp, scratch, out);
        total += out.len();
    }
    total
}

#[test]
fn steady_state_search_is_allocation_free() {
    let ds = generate(Kind::DeepLike, 4000, 64, 16, 31);
    let sp = SearchParams { nprobe: 8, k: 10 };
    let cases: [(&str, VectorMode); 5] = [
        ("unc64", VectorMode::Flat),
        ("compact", VectorMode::Flat),
        ("ef", VectorMode::Flat),
        ("roc", VectorMode::Flat),
        ("compact", VectorMode::PqCompressed { m: 4, bits: 8 }),
    ];
    for (codec, vectors) in cases {
        let label = match &vectors {
            VectorMode::PqCompressed { .. } => "pq-compressed",
            _ => codec,
        };
        let idx = IvfIndex::build(
            &ds.data,
            ds.dim,
            &IvfBuildParams {
                k: 32,
                id_codec: codec.into(),
                vectors: vectors.clone(),
                threads: 2,
                ..Default::default()
            },
        );
        let mut scratch = SearchScratch::default();
        let mut out = Vec::new();
        // Two warm passes: the first grows every buffer, the second lets
        // monotone structures (e.g. the ROC RankSet bucket layout, which
        // only rebuilds toward more buckets) settle completely.
        let warm_a = run_all_queries(&idx, &ds, &sp, &mut scratch, &mut out);
        let warm_b = run_all_queries(&idx, &ds, &sp, &mut scratch, &mut out);
        assert_eq!(warm_a, warm_b, "{label}: warm passes disagree");
        let before = allocation_count();
        let measured = run_all_queries(&idx, &ds, &sp, &mut scratch, &mut out);
        let after = allocation_count();
        assert_eq!(measured, warm_a, "{label}: measured pass disagrees");
        assert_eq!(
            after - before,
            0,
            "{label}: steady-state pass performed {} heap allocations over {} queries",
            after - before,
            ds.nq
        );
    }
}

#[test]
fn warm_passes_return_identical_results() {
    // Companion sanity: the reused-scratch results on the measured pass
    // match a fresh-scratch search (reuse must never change results).
    let ds = generate(Kind::SiftLike, 3000, 32, 16, 32);
    let sp = SearchParams { nprobe: 8, k: 10 };
    for codec in ["roc", "ef"] {
        let idx = IvfIndex::build(
            &ds.data,
            ds.dim,
            &IvfBuildParams { k: 32, id_codec: codec.into(), threads: 2, ..Default::default() },
        );
        let mut scratch = SearchScratch::default();
        let mut out = Vec::new();
        run_all_queries(&idx, &ds, &sp, &mut scratch, &mut out);
        for qi in 0..ds.nq {
            idx.search_into(ds.query(qi), &sp, &mut scratch, &mut out);
            let mut fresh = SearchScratch::default();
            let want = idx.search(ds.query(qi), &sp, &mut fresh);
            assert_eq!(out, want, "{codec} query {qi}");
        }
    }
}
