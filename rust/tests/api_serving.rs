//! Backend-generic serving acceptance: the coordinator serving a
//! [`GraphIndex`] must return exactly what a direct [`beam_search`] over
//! the same store and entry set returns — for HNSW and NSG, at several
//! beam widths — proving graph backends ride the same batched path as
//! IVF without result drift.

use std::sync::Arc;
use std::time::Duration;
use zann::api::{AnnIndex, GraphIndex, QueryParams};
use zann::coordinator::{Coordinator, ServeConfig};
use zann::datasets::{generate, Dataset, Kind};
use zann::graph::hnsw::{Hnsw, HnswParams};
use zann::graph::nsg::{Nsg, NsgParams};
use zann::graph::{beam_search, VisitedSet};

fn serve_matches_direct_beam_search(gi: Arc<GraphIndex>, ds: &Dataset, k: usize, efs: &[usize]) {
    let queries: Vec<Vec<f32>> = (0..ds.nq).map(|qi| ds.query(qi).to_vec()).collect();
    let mut visited = VisitedSet::default();
    let mut neigh = Vec::new();
    for &ef in efs {
        let coord = Coordinator::start(
            gi.clone(),
            None,
            ServeConfig {
                batch_size: 8,
                max_wait: Duration::from_millis(1),
                search: QueryParams { k, ef, nprobe: 0 },
                scan_threads: 2,
                ..Default::default()
            },
        );
        let responses = coord.client.search_many(queries.clone()).unwrap();
        for (qi, resp) in responses.iter().enumerate() {
            let want = beam_search(
                gi.store(),
                gi.data(),
                gi.dim(),
                gi.entries(),
                ds.query(qi),
                ef.max(k),
                k,
                &mut visited,
                &mut neigh,
            );
            assert_eq!(
                resp.results, want,
                "{:?} ef={ef} query {qi}: served != direct beam search",
                gi.family()
            );
            assert!(!resp.via_pjrt, "graph backends have no PJRT coarse stage");
            assert!(resp.results.len() <= k);
        }
        coord.stop();
    }
}

#[test]
fn coordinator_over_nsg_matches_beam_search_at_every_ef() {
    let ds = generate(Kind::DeepLike, 1500, 25, 8, 81);
    let nsg = Nsg::build(
        &ds.data,
        ds.dim,
        &NsgParams { r: 16, knn_k: 24, threads: 2, seed: 6, ..Default::default() },
    );
    let gi = Arc::new(GraphIndex::from_nsg(&nsg, &ds.data, "roc").unwrap());
    serve_matches_direct_beam_search(gi, &ds, 5, &[8, 32, 64]);
}

#[test]
fn coordinator_over_hnsw_matches_beam_search_at_every_ef() {
    let ds = generate(Kind::DeepLike, 1500, 25, 8, 82);
    let h = Hnsw::build(&ds.data, ds.dim, &HnswParams { m: 12, ef_construction: 60, seed: 6 });
    let gi = Arc::new(GraphIndex::from_hnsw(&h, &ds.data, "ef").unwrap());
    serve_matches_direct_beam_search(gi, &ds, 5, &[8, 32, 64]);
}

#[test]
fn saved_graph_serves_identically_after_reopen() {
    use zann::api::persist;
    let ds = generate(Kind::DeepLike, 1000, 15, 8, 83);
    let nsg = Nsg::build(
        &ds.data,
        ds.dim,
        &NsgParams { r: 16, knn_k: 24, threads: 2, seed: 7, ..Default::default() },
    );
    let gi = GraphIndex::from_nsg(&nsg, &ds.data, "roc").unwrap();
    let reopened = Arc::new(persist::open_graph_bytes(gi.to_bytes().unwrap()).unwrap());
    // The reopened index's store decodes the verbatim blobs, so serving
    // it must still equal a beam search over its own (borrowed) store.
    serve_matches_direct_beam_search(reopened, &ds, 5, &[16, 48]);
}
