//! Persistence acceptance tests: `save` → `open` → `search` must be
//! **bit-identical** to the in-memory index for every per-list codec ×
//! `VectorMode`, the file must weigh ≈ the compressed payload (the
//! paper's storage claim survives the disk round-trip), and corrupt or
//! truncated files must fail with errors, never panics.

use zann::api::{persist, AnnIndex, AnnScratch, GraphFamily, GraphIndex, QueryParams};
use zann::codecs::PER_LIST_CODECS;
use zann::datasets::{generate, Dataset, Kind};
use zann::graph::hnsw::{Hnsw, HnswParams};
use zann::graph::nsg::{Nsg, NsgParams};
use zann::index::{IvfBuildParams, IvfIndex, SearchParams, SearchScratch, VectorMode};

fn build_ivf(ds: &Dataset, codec: &str, vectors: VectorMode) -> IvfIndex {
    IvfIndex::build(
        &ds.data,
        ds.dim,
        &IvfBuildParams {
            k: 32,
            id_codec: codec.into(),
            vectors,
            threads: 2,
            ..Default::default()
        },
    )
}

#[test]
fn ivf_roundtrip_bit_identical_for_every_codec_and_vector_mode() {
    let ds = generate(Kind::DeepLike, 3000, 25, 16, 71);
    let modes = [
        VectorMode::Flat,
        VectorMode::Pq { m: 4, bits: 8 },
        VectorMode::PqCompressed { m: 4, bits: 8 },
    ];
    let sp = SearchParams { nprobe: 8, k: 10 };
    let p = QueryParams { nprobe: 8, k: 10, ..Default::default() };
    for codec in PER_LIST_CODECS {
        for mode in &modes {
            let label = format!("{codec}/{mode:?}");
            let idx = build_ivf(&ds, codec, mode.clone());
            let bytes = idx.to_bytes().unwrap_or_else(|e| panic!("{label}: {e}"));

            // File size ≈ payload + bounded metadata: centroids
            // (k·dim·4), offset tables, and for PQ modes the codebook
            // (m·2^bits·dsub·4) — none of which count as compressed
            // payload in the paper's accounting.
            let payload = (idx.id_bits() + idx.code_bits()).div_ceil(8);
            let codebook = match mode {
                VectorMode::Flat => 0u64,
                VectorMode::Pq { m, bits } | VectorMode::PqCompressed { m, bits } => {
                    (*m as u64) * (1u64 << bits) * (ds.dim / m) as u64 * 4
                }
            };
            let overhead = (idx.k * ds.dim * 4) as u64          // centroids
                + codebook
                + (3 * (idx.k + 1) * 8 + idx.k * 4 * 8) as u64  // offset tables
                + 4096;                                          // header + framing
            assert!(
                (bytes.len() as u64) >= payload,
                "{label}: file {} smaller than payload {payload}",
                bytes.len()
            );
            assert!(
                (bytes.len() as u64) <= payload + overhead,
                "{label}: file {} exceeds payload {payload} + overhead {overhead}",
                bytes.len()
            );

            let back = persist::open_ivf_bytes(bytes.clone())
                .unwrap_or_else(|e| panic!("{label}: reopen: {e:?}"));
            assert_eq!(back.id_bits(), idx.id_bits(), "{label}: id bits");
            assert_eq!(back.code_bits(), idx.code_bits(), "{label}: code bits");
            assert_eq!(back.id_codec_name(), idx.id_codec_name(), "{label}");
            assert_eq!(back.k, idx.k, "{label}");

            // Every cluster's decoded list is byte-for-byte the same
            // order (the blobs were written verbatim).
            for c in 0..idx.k {
                assert_eq!(back.decode_list(c), idx.decode_list(c), "{label}: cluster {c}");
            }

            // Search results — distances and ids — are bit-identical,
            // through the inherent API and the trait object alike.
            let dyn_back = persist::open_bytes(bytes).unwrap();
            let mut s1 = SearchScratch::default();
            let mut s2 = SearchScratch::default();
            let mut s3 = AnnScratch::default();
            let mut via_dyn = Vec::new();
            for qi in 0..ds.nq {
                let want = idx.search(ds.query(qi), &sp, &mut s1);
                let got = back.search(ds.query(qi), &sp, &mut s2);
                assert_eq!(got, want, "{label}: query {qi}");
                dyn_back.search_into(ds.query(qi), &p, &mut s3, &mut via_dyn);
                assert_eq!(via_dyn, want, "{label}: query {qi} via dyn AnnIndex");
            }
        }
    }
}

#[test]
fn wavelet_id_stores_refuse_to_persist_with_an_actionable_error() {
    let ds = generate(Kind::DeepLike, 1500, 1, 8, 72);
    for codec in ["wt", "wt1"] {
        let idx = build_ivf(&ds, codec, VectorMode::Flat);
        let err = idx.to_bytes().expect_err("wavelet stores are not persistable yet");
        let msg = format!("{err}");
        assert!(msg.contains("wavelet") && msg.contains("roc"), "{codec}: {msg}");
    }
}

#[test]
fn graph_roundtrip_bit_identical_for_nsg_and_hnsw() {
    let ds = generate(Kind::DeepLike, 1200, 20, 8, 73);
    let nsg = Nsg::build(
        &ds.data,
        ds.dim,
        &NsgParams { r: 16, knn_k: 24, threads: 2, seed: 4, ..Default::default() },
    );
    let hnsw = Hnsw::build(&ds.data, ds.dim, &HnswParams { m: 12, ef_construction: 60, seed: 4 });
    let indexes = [
        GraphIndex::from_nsg(&nsg, &ds.data, "roc").unwrap(),
        GraphIndex::from_nsg(&nsg, &ds.data, "compact").unwrap(),
        GraphIndex::from_hnsw(&hnsw, &ds.data, "ef").unwrap(),
    ];
    for gi in &indexes {
        let label = format!("{:?}/{}", gi.family(), gi.stats().codec);
        let bytes = gi.to_bytes().unwrap_or_else(|e| panic!("{label}: {e}"));
        let back = persist::open_graph_bytes(bytes).unwrap_or_else(|e| panic!("{label}: {e:?}"));
        assert_eq!(back.family(), gi.family(), "{label}");
        assert_eq!(back.entries(), gi.entries(), "{label}");
        assert_eq!(back.stats().link_bits, gi.stats().link_bits, "{label}");
        assert_eq!(back.stats().codec, gi.stats().codec, "{label}");
        let mut s1 = AnnScratch::default();
        let mut s2 = AnnScratch::default();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for &ef in &[8usize, 32, 64] {
            let p = QueryParams { k: 5, ef, nprobe: 0 };
            for qi in 0..ds.nq {
                gi.search_into(ds.query(qi), &p, &mut s1, &mut a);
                back.search_into(ds.query(qi), &p, &mut s2, &mut b);
                assert_eq!(a, b, "{label}: ef={ef} query {qi}");
            }
        }
    }
    assert_eq!(indexes[0].family(), GraphFamily::Nsg);
}

#[test]
fn corrupt_and_truncated_files_error_cleanly() {
    let ds = generate(Kind::DeepLike, 1200, 1, 8, 74);
    let idx = build_ivf(&ds, "roc", VectorMode::Flat);
    let good = idx.to_bytes().unwrap();
    assert!(persist::open_bytes(good.clone()).is_ok(), "baseline must open");

    // Bad magic.
    let mut bad = good.clone();
    bad[0] ^= 0xff;
    let err = persist::open_bytes(bad).expect_err("bad magic");
    assert!(format!("{err}").contains("magic"), "{err}");
    // Unsupported version.
    let mut bad = good.clone();
    bad[4] = 0x2a;
    let err = persist::open_bytes(bad).expect_err("future version");
    assert!(format!("{err}").contains("version"), "{err}");
    // Unknown kind byte.
    let mut bad = good.clone();
    bad[6] = 77;
    let err = persist::open_bytes(bad).expect_err("unknown kind");
    assert!(format!("{err}").contains("kind"), "{err}");
    // Truncations: every strict prefix must be an error (a cut either
    // breaks the framing or drops a required section), never a panic.
    for cut in [0, 4, 7, 8, good.len() / 3, good.len() / 2, good.len() - 1] {
        assert!(
            persist::open_bytes(good[..cut].to_vec()).is_err(),
            "truncation at {cut}/{} must fail",
            good.len()
        );
    }
    // Kind-checked typed opens.
    assert!(persist::open_ivf_bytes(good.clone()).is_ok());
    let err = persist::open_graph_bytes(good).expect_err("ivf file is not a graph");
    assert!(format!("{err}").contains("kind"), "{err}");
}
