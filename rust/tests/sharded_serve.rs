//! Sharded-serving acceptance: scatter-gather over N shards must be
//! *bit-identical* to searching one index built over the union of the
//! rows — for every per-list codec, both ingest routers, and planted
//! exact-distance ties — and the live node must degrade (not hang or
//! poison its siblings) when a shard worker panics mid-query.
//!
//! Bit-identity holds by construction (one global coarse quantizer shared
//! across shards + the `(distance, ext_id)` merge in
//! `zann::serve::sharded`); these tests are the end-to-end proof.

use std::sync::Arc;
use zann::api::{persist, AnnIndex, AnnScratch, QueryParams};
use zann::codecs::PER_LIST_CODECS;
use zann::datasets::{generate, Kind};
use zann::index::{IvfBuildParams, IvfIndex};
use zann::serve::{DegradePolicy, NodeConfig, RouterKind, ServeNode, ShardedBuildParams, ShardedIndex};

/// Deep-like rows with planted exact-distance tie groups: the rows in
/// each group are bytewise identical, so any query is equidistant from
/// all of them and only the `(distance, id)` pin can order the results.
fn tied_dataset(n: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<Vec<u32>>) {
    let ds = generate(Kind::DeepLike, n, 1, dim, seed);
    let mut data = ds.data;
    // Two groups, members spread across the id space so every router
    // splits at least one group over multiple shards.
    let groups: Vec<Vec<u32>> = vec![
        vec![17, 411, 902, 1673],
        vec![230, 1111, 1999],
    ];
    for group in &groups {
        let src = group[0] as usize * dim;
        let proto: Vec<f32> = data[src..src + dim].to_vec();
        for &id in &group[1..] {
            data[id as usize * dim..(id as usize + 1) * dim].copy_from_slice(&proto);
        }
    }
    (data, groups)
}

fn ivf_params(codec: &str) -> IvfBuildParams {
    IvfBuildParams { k: 16, id_codec: codec.into(), threads: 2, seed: 7, ..Default::default() }
}

fn search(idx: &dyn AnnIndex, q: &[f32], p: &QueryParams) -> Vec<(f32, u32)> {
    let mut scratch = AnnScratch::default();
    let mut out = Vec::new();
    idx.search_into(q, p, &mut scratch, &mut out);
    out
}

/// The tentpole acceptance property: for every per-list codec and both
/// routers, a 4-shard index answers every query — including the planted
/// tie queries — with exactly the single-index result vector (same
/// distances to the bit, same ids, same order).
#[test]
fn sharded_search_is_bit_identical_to_single_index_for_every_codec() {
    let (n, dim) = (2000usize, 8usize);
    let (data, groups) = tied_dataset(n, dim, 901);
    let qs = generate(Kind::DeepLike, 8, 8, dim, 77).queries;
    let p = QueryParams { k: 10, nprobe: 4, ef: 0 };
    for codec in PER_LIST_CODECS {
        let single = IvfIndex::build(&data, dim, &ivf_params(codec));
        for router in [RouterKind::Hash, RouterKind::Kmeans] {
            let sharded = ShardedIndex::build(
                &data,
                dim,
                &ShardedBuildParams { shards: 4, router, ivf: ivf_params(codec) },
            )
            .unwrap();
            assert_eq!(sharded.num_shards(), 4);
            for qi in 0..8 {
                let q = &qs[qi * dim..(qi + 1) * dim];
                let got = search(&sharded, q, &p);
                let want = search(&single, q, &p);
                assert_eq!(got.len(), p.k);
                assert!(
                    got.iter().zip(&want).all(|(a, b)| a.0.to_bits() == b.0.to_bits() && a.1 == b.1),
                    "{codec}/{router:?} query {qi}: sharded != single\n got {got:?}\nwant {want:?}"
                );
            }
            // Tie queries: querying a duplicated row makes its whole
            // group exact-distance-tied at 0; the merge must return the
            // group in ascending global id, identically on both paths.
            for group in &groups {
                let q = &data[group[0] as usize * dim..(group[0] as usize + 1) * dim];
                let got = search(&sharded, q, &p);
                let want = search(&single, q, &p);
                assert_eq!(
                    got.iter().map(|r| (r.0.to_bits(), r.1)).collect::<Vec<_>>(),
                    want.iter().map(|r| (r.0.to_bits(), r.1)).collect::<Vec<_>>(),
                    "{codec}/{router:?}: tie group diverged"
                );
                let tied: Vec<u32> =
                    got.iter().filter(|r| r.0 == got[0].0).map(|r| r.1).collect();
                for id in group {
                    assert!(tied.contains(id), "{codec}/{router:?}: {id} missing from tie group");
                }
                let mut sorted = tied.clone();
                sorted.sort_unstable();
                assert_eq!(tied, sorted, "{codec}/{router:?}: ties not in ascending id order");
            }
        }
    }
}

/// Same property through the file format: a sharded container saved to
/// disk and reopened generically serves bit-identical results.
#[test]
fn saved_sharded_container_reopens_bit_identically() {
    let ds = generate(Kind::DeepLike, 1500, 6, 8, 31);
    let sharded = ShardedIndex::build(
        &ds.data,
        ds.dim,
        &ShardedBuildParams { shards: 3, router: RouterKind::Kmeans, ivf: ivf_params("roc") },
    )
    .unwrap();
    let dir = std::env::temp_dir().join(format!("zann-sharded-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sharded.zann");
    sharded.save(&path).unwrap();
    let generic = persist::open(&path).unwrap();
    let typed = persist::open_sharded(&path).unwrap();
    assert_eq!(typed.num_shards(), 3);
    let p = QueryParams { k: 5, nprobe: 4, ef: 0 };
    for qi in 0..ds.nq {
        let q = ds.query(qi);
        let want = search(&sharded, q, &p);
        assert_eq!(search(&*generic, q, &p), want, "generic reopen diverged at query {qi}");
        assert_eq!(search(&typed, q, &p), want, "typed reopen diverged at query {qi}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// A shard whose search panics on a poisoned query (NaN lead) — stands
/// in for any mid-query worker fault.
struct PanickyShard {
    dim: usize,
}

impl AnnIndex for PanickyShard {
    fn kind(&self) -> zann::api::IndexKind {
        zann::api::IndexKind::Ivf
    }
    fn dim(&self) -> usize {
        self.dim
    }
    fn len(&self) -> usize {
        1
    }
    fn stats(&self) -> zann::api::IndexStats {
        zann::api::IndexStats {
            kind: zann::api::IndexKind::Ivf,
            n: 1,
            dim: self.dim,
            edges: 0,
            codec: "chaos".into(),
            id_bits: 0,
            code_bits: 0,
            link_bits: 0,
            live: 1,
            deleted: 0,
            buffer_rows: 0,
            aux_bits: 0,
            checksummed: false,
            segments: Vec::new(),
        }
    }
    fn search_into(
        &self,
        query: &[f32],
        params: &QueryParams,
        _scratch: &mut AnnScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        if query[0].is_nan() {
            panic!("chaos shard: poisoned query");
        }
        out.clear();
        out.push((f32::MAX, 0));
        let _ = params;
    }
    fn to_bytes(&self) -> anyhow::Result<Vec<u8>> {
        anyhow::bail!("chaos shard is not serializable")
    }
}

/// End-to-end chaos: swap a panicking shard into a live node, hit it
/// mid-query, and require (a) a structured `Failed` response — never a
/// hang — with the degrade policy deciding whether sibling results
/// still flow, and (b) full recovery on the next clean query.
#[test]
fn shard_worker_panic_degrades_per_policy_and_node_recovers() {
    let ds = generate(Kind::DeepLike, 1200, 4, 8, 53);
    for policy in [DegradePolicy::Partial, DegradePolicy::Fail] {
        let sharded = ShardedIndex::build(
            &ds.data,
            ds.dim,
            &ShardedBuildParams { shards: 3, router: RouterKind::Hash, ivf: ivf_params("ef") },
        )
        .unwrap();
        let cfg = NodeConfig { policy, ..Default::default() };
        let node = ServeNode::start_static(sharded, cfg).unwrap();
        let clean = ds.query(0).to_vec();
        let before = node.search_raw(&clean).unwrap();
        assert!(before.is_ok(), "baseline query must serve");

        node.swap_shard(1, Arc::new(PanickyShard { dim: ds.dim }), vec![0], None).unwrap();
        let mut poisoned = clean.clone();
        poisoned[0] = f32::NAN;
        let resp = node.search_raw(&poisoned).unwrap();
        assert_eq!(
            resp.status,
            zann::coordinator::ResponseStatus::Failed,
            "{policy:?}: panicked shard must surface as Failed"
        );
        match policy {
            DegradePolicy::Fail => assert!(resp.results.is_empty(), "Fail policy returns nothing"),
            DegradePolicy::Partial => {
                // NaN distances from healthy shards are legitimate here;
                // the point is the merge still produced an answer.
            }
        }
        // The panicked worker was respawned: the same node keeps serving.
        let after = node.search_raw(&clean).unwrap();
        assert!(after.is_ok(), "{policy:?}: node must recover after a shard panic");
        node.stop();
    }
}
