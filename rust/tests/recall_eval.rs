//! Accuracy-evaluation invariants behind the recall harness (PR: "no
//! impact on accuracy" as a tested claim):
//!
//! 1. `exact_knn` groundtruth is thread-count invariant — including on
//!    exact distance ties, which are pinned by the (distance, id)
//!    ordering — so a baseline computed on one machine is comparable to
//!    a run on any other.
//! 2. Every lossless per-list id codec yields bit-identical search
//!    results to the uncompressed store over the same clustering.
//! 3. A `DynamicIvf` that has been through a full delete → insert →
//!    compact churn cycle reaches exactly the recall of a from-scratch
//!    static build over the same live set.

use zann::codecs::PER_LIST_CODECS;
use zann::datasets::{generate, groundtruth, Kind};
use zann::dynamic::{CompactionPolicy, DynamicBuildParams, DynamicIvf};
use zann::index::{IvfBuildParams, IvfIndex, SearchParams, SearchScratch};
use zann::quant::{kmeans, l2_sq};
use zann::util::Rng;

/// Single-threaded brute-force reference: all distances, sorted by
/// (distance, id) — the tie-break `exact_knn` documents.
fn reference_knn(data: &[f32], queries: &[f32], dim: usize, k: usize) -> Vec<u32> {
    let n = data.len() / dim;
    let nq = queries.len() / dim;
    let mut out = Vec::with_capacity(nq * k);
    for qi in 0..nq {
        let q = &queries[qi * dim..(qi + 1) * dim];
        let mut d: Vec<(f32, u32)> = (0..n)
            .map(|i| (l2_sq(q, &data[i * dim..(i + 1) * dim]), i as u32))
            .collect();
        d.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        out.extend(d.iter().take(k).map(|&(_, id)| id));
    }
    out
}

#[test]
fn exact_knn_is_thread_count_invariant() {
    let dim = 8;
    let ds = generate(Kind::DeepLike, 600, 16, dim, 11);
    let want = reference_knn(&ds.data, &ds.queries, dim, 10);
    for threads in [1, 3, 8] {
        let got = groundtruth::exact_knn(&ds.data, &ds.queries, dim, 10, threads);
        assert_eq!(got, want, "threads={threads} diverged from the 1-thread reference");
    }
}

#[test]
fn exact_knn_pins_distance_ties_by_id() {
    // Every vector appears three times, so each query's top-k straddles
    // groups of exactly-tied distances; only the documented (distance,
    // id) tie-break makes the output well-defined across thread counts.
    let dim = 4;
    let base = generate(Kind::DeepLike, 50, 12, dim, 3);
    let mut data = Vec::with_capacity(3 * base.data.len());
    for _ in 0..3 {
        data.extend_from_slice(&base.data);
    }
    let want = reference_knn(&data, &base.queries, dim, 7);
    for threads in [1, 4, 8] {
        let got = groundtruth::exact_knn(&data, &base.queries, dim, 7, threads);
        assert_eq!(got, want, "threads={threads} broke the tie ordering");
    }
    // The ties really are there: each group of k=7 must contain at least
    // one duplicated pair (ids i and i+50 hold identical vectors).
    let row = &want[..7];
    assert!(
        row.iter().any(|&id| row.contains(&(id + 50)) || row.contains(&(id + 100))),
        "test setup lost its duplicates: {row:?}"
    );
}

#[test]
fn every_per_list_codec_matches_the_uncompressed_store() {
    let (n, nq, dim, seed, threads) = (3000, 24, 8, 42, 2);
    let ds = generate(Kind::SiftLike, n, nq, dim, seed);
    let k = 32;
    let cents = kmeans::train(
        &ds.data,
        dim,
        &kmeans::KmeansConfig { k, iters: 6, seed, threads, ..Default::default() },
    );
    let kk = cents.len() / dim;
    let assign = kmeans::assign(&ds.data, dim, &cents, threads);
    let build = |codec: &str| {
        IvfIndex::build_preassigned(
            &ds.data,
            dim,
            &cents,
            &assign,
            &IvfBuildParams { k: kk, id_codec: codec.into(), threads, seed, ..Default::default() },
            kk,
        )
    };
    let search = |idx: &IvfIndex, nprobe: usize| -> Vec<Vec<(u32, u32)>> {
        let sp = SearchParams { k: 10, nprobe };
        let mut scratch = SearchScratch::default();
        let mut out = Vec::new();
        (0..nq)
            .map(|qi| {
                idx.search_into(ds.query(qi), &sp, &mut scratch, &mut out);
                out.iter().map(|&(d, id)| (d.to_bits(), id)).collect()
            })
            .collect()
    };
    let reference = build(PER_LIST_CODECS[0]);
    assert_eq!(PER_LIST_CODECS[0], "unc64");
    for &nprobe in &[4usize, 32] {
        let want = search(&reference, nprobe);
        for codec in &PER_LIST_CODECS[1..] {
            let got = search(&build(codec), nprobe);
            assert_eq!(
                got, want,
                "codec {codec} diverged from unc64 at nprobe={nprobe}: losslessness violated"
            );
        }
    }
}

#[test]
fn post_churn_dynamic_recall_equals_static_rebuild() {
    let (n0, moved, nq, dim, seed, threads) = (4000usize, 800usize, 30usize, 8usize, 9u64, 2usize);
    let ds = generate(Kind::DeepLike, n0 + moved, nq, dim, seed);
    let mut idx = DynamicIvf::build(
        &ds.data[..n0 * dim],
        dim,
        &DynamicBuildParams {
            ivf: IvfBuildParams {
                k: 64,
                id_codec: "roc".into(),
                threads,
                seed,
                ..Default::default()
            },
            policy: CompactionPolicy::default(),
        },
    )
    .expect("build");
    let mut rng = Rng::new(seed ^ 0xc0ffee);
    for id in rng.sample_distinct(n0 as u64, moved) {
        idx.delete(id as u32).expect("delete");
    }
    idx.add(&ds.data[n0 * dim..]).expect("add");
    idx.compact().expect("compact");

    // Groundtruth over the live set, in external-id space (external id e
    // is row e of the generated data — adds were sequential).
    let live = idx.live_ids();
    // `moved` deletes and `moved` inserts cancel out.
    assert_eq!(live.len(), n0);
    let mut live_data = Vec::with_capacity(live.len() * dim);
    for &e in &live {
        live_data.extend_from_slice(ds.vector(e as usize));
    }
    let gt_k = 10;
    let gt: Vec<u32> = groundtruth::exact_knn(&live_data, &ds.queries, dim, gt_k, threads)
        .into_iter()
        .map(|row| live[row as usize])
        .collect();

    let (stat, ext_of) = idx.rebuild_static().expect("rebuild");
    let sp = SearchParams { k: gt_k, nprobe: 16 };
    let mut s_dyn = SearchScratch::default();
    let mut s_stat = SearchScratch::default();
    let (mut dyn_ids, mut stat_ids) = (Vec::new(), Vec::new());
    let (mut d_out, mut s_out) = (Vec::new(), Vec::new());
    for qi in 0..nq {
        let q = ds.query(qi);
        idx.search_into(q, &sp, &mut s_dyn, &mut d_out);
        stat.search_into(q, &sp, &mut s_stat, &mut s_out);
        dyn_ids.push(d_out.iter().map(|&(_, id)| id).collect::<Vec<u32>>());
        stat_ids.push(s_out.iter().map(|&(_, id)| ext_of[id as usize]).collect::<Vec<u32>>());
    }
    let r_dyn = groundtruth::recall_at_k(&gt, gt_k, &dyn_ids, gt_k);
    let r_stat = groundtruth::recall_at_k(&gt, gt_k, &stat_ids, gt_k);
    assert_eq!(
        r_dyn, r_stat,
        "post-churn dynamic recall must equal the from-scratch static build"
    );
    // And not vacuously: at nprobe=16 of K=64 the index actually finds
    // most true neighbors.
    assert!(r_dyn > 0.5, "churned index recall collapsed: {r_dyn}");
    // Stronger than equal recall: result lists are identical query by
    // query once static row ids are mapped to external ids.
    assert_eq!(dyn_ids, stat_ids, "result parity with the static rebuild broken");
}
