#!/usr/bin/env python3
"""Generate the frozen version-1 single-segment IVF container fixtures.

These bytes replicate, independently of the Rust writer, the container
layout `IvfIndex::to_container_bytes` produced *before* the dynamic
(multi-segment) subsystem existed: `ZANN` magic, container version 1,
kind 1 (IVF), sections HEAD/CENT/OFFS/IDOF/IDBL/VECS, Flat vectors,
with `unc64` (64-bit words per id) and `compact` (ceil(log2 N)-bit
packed) id streams. `rust/tests/persist_compat.rs` opens them and
asserts stats + search results bit-identically, so any reader change
that would orphan pre-dynamic index files fails CI.

The dataset is tiny and fully deterministic: n=12, dim=4, k=2;
id i lands in cluster i%2; row(i)[j] = center(i) + i*i/32 + j/16 with
center 0.0 / 8.0 (all values exact in f32; the quadratic term keeps
every pairwise distance distinct, so search comparisons are
tie-free). Rewriting the fixtures
requires rerunning this script AND updating the constants in
persist_compat.rs — by design, so it cannot happen accidentally.
"""
import struct
from pathlib import Path

N, DIM, K = 12, 4, 2


def row(i):
    center = 0.0 if i % 2 == 0 else 8.0
    return [center + (i * i) / 32.0 + j / 16.0 for j in range(DIM)]


LISTS = [[i for i in range(N) if i % 2 == 0], [i for i in range(N) if i % 2 == 1]]
CENTROIDS = [0.0] * DIM + [8.0] * DIM


def put_u64s(vals):
    return struct.pack("<Q", len(vals)) + b"".join(struct.pack("<Q", v) for v in vals)


def put_f32s(vals):
    return struct.pack("<Q", len(vals)) + b"".join(struct.pack("<f", v) for v in vals)


def put_str(s):
    b = s.encode()
    return struct.pack("<Q", len(b)) + b


def section(tag, payload):
    assert len(tag) == 4
    return tag + struct.pack("<Q", len(payload)) + payload


def head(codec, id_bits):
    return (
        struct.pack("<Q", DIM)
        + struct.pack("<Q", N)
        + struct.pack("<Q", K)
        + put_str(codec)
        + struct.pack("<B", 0)      # vector mode 0 = Flat
        + struct.pack("<Q", 0)      # pq m
        + struct.pack("<I", 0)      # pq bits
        + struct.pack("<Q", id_bits)
        + struct.pack("<Q", N * DIM * 32)  # code_bits: flat f32 rows
    )


def encode_unc64(ids):
    return b"".join(struct.pack("<Q", i) for i in ids), len(ids) * 64


# --- interleaved rANS (the `ans-i4` codec), replicated independently ---
# of the Rust coder: 4 states round-robin over the sorted ids (state of
# symbol i is i % 4), symbols encoded in reverse order under
# Uniform([0, universe)) with the standard 64-bit-head / 32-bit-word
# renormalization, all states pushing to one shared word stack. Blob
# layout: u32 word count, stream words (LE), then the 4 final heads
# (LE u64 each). Bits accounting: 32 per stream word + 64 per head.

ANS_LOW = 1 << 32
ANS_WAYS = 4


def _boundary(z, m):
    return (z << 32) // m


def _ans_encode_uniform(head, stream, x, m):
    c32 = _boundary(x, m)
    f32 = _boundary(x + 1, m) - c32
    if f32 < ANS_LOW:
        limit = f32 << 32
        while head >= limit:
            stream.append(head & 0xFFFFFFFF)
            head >>= 32
    return (head // f32) * ANS_LOW + c32 + head % f32


def _ans_decode_uniform(head, stream, cursor, m):
    slot = head & 0xFFFFFFFF
    v = (slot * m) >> 32
    lo, hi = _boundary(v, m), _boundary(v + 1, m)
    if hi <= slot:
        v += 1
        lo, hi = hi, _boundary(v + 1, m)
    head = (hi - lo) * (head >> 32) + slot - lo
    while head < ANS_LOW and cursor > 0:
        cursor -= 1
        head = (head << 32) | stream[cursor]
    return head, cursor, v


def encode_ansi4(ids, universe=N):
    srt = sorted(ids)
    heads = [ANS_LOW] * ANS_WAYS
    stream = []
    for i in range(len(srt) - 1, -1, -1):
        w = i % ANS_WAYS
        heads[w] = _ans_encode_uniform(heads[w], stream, srt[i], universe)
    # Self-check: the mirrored decode must reproduce the sorted list and
    # drain every state back to the initial value.
    dheads, cursor, out = list(heads), len(stream), []
    for i in range(len(srt)):
        w = i % ANS_WAYS
        dheads[w], cursor, v = _ans_decode_uniform(dheads[w], stream, cursor, universe)
        out.append(v)
    assert out == srt and cursor == 0 and all(h == ANS_LOW for h in dheads)
    blob = struct.pack("<I", len(stream))
    blob += b"".join(struct.pack("<I", w) for w in stream)
    blob += b"".join(struct.pack("<Q", h) for h in heads)
    return blob, len(stream) * 32 + ANS_WAYS * 64


def encode_compact(ids, universe=N):
    width = max((universe - 1).bit_length(), 1)  # bits_for(12) = 4
    acc, nbits, words = 0, 0, []
    for i in ids:
        acc |= i << nbits
        nbits += width
        while nbits >= 64:
            words.append(acc & ((1 << 64) - 1))
            acc >>= 64
            nbits -= 64
    if nbits > 0 or not words:
        words.append(acc & ((1 << 64) - 1))
    # The rust codec serializes whole u64 words, little-endian.
    return b"".join(struct.pack("<Q", w) for w in words), len(ids) * width


def container(codec, encode):
    blobs, id_bits, idof = [], 0, [0]
    for lst in LISTS:
        blob, bits = encode(lst)
        blobs.append(blob)
        id_bits += bits
        idof.append(idof[-1] + len(blob))
    offsets = [0, len(LISTS[0]), N]
    vecs = [v for lst in LISTS for i in lst for v in row(i)]
    out = b"ZANN" + struct.pack("<H", 1) + bytes([1, 0])  # version 1, kind IVF
    out += section(b"HEAD", head(codec, id_bits))
    out += section(b"CENT", put_f32s(CENTROIDS))
    out += section(b"OFFS", put_u64s(offsets))
    out += section(b"IDOF", put_u64s(idof))
    out += section(b"IDBL", b"".join(blobs))
    out += section(b"VECS", put_f32s(vecs))
    return out


def main():
    here = Path(__file__).parent
    for codec, encode, fname in [
        ("unc64", encode_unc64, "v1_ivf_unc64.zann"),
        ("compact", encode_compact, "v1_ivf_compact.zann"),
        # The interleaved-ANS layout, frozen from day one so the shared
        # word stack + trailing heads framing can never drift silently.
        ("ans-i4", encode_ansi4, "v1_ivf_ansi4.zann"),
    ]:
        path = here / fname
        data = container(codec, encode)
        path.write_bytes(data)
        id_bits = sum(encode(lst)[1] for lst in LISTS)
        print(f"wrote {path} ({len(data)} bytes, id_bits={id_bits})")


if __name__ == "__main__":
    main()
