//! Corrupt-stream property tests for every per-list codec: seeded bit
//! flips, truncations, length-field lies and pure garbage fed to
//! `try_decode_into` must produce a structured `Err` or a well-formed
//! `Ok` — never a panic, an abort, or a hang. Each case runs on a
//! watchdog thread with a time guard, so an accidental unbounded decode
//! loop fails the test instead of wedging the suite.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::time::Duration;
use zann::codecs::{CodecSpec, DecodeScratch, PER_LIST_CODECS};
use zann::util::Rng;

const TIME_GUARD: Duration = Duration::from_secs(10);

/// Strictly ascending distinct id list + its encoded stream.
fn encoded_list(codec_name: &str, universe: u32, n: usize, seed: u64) -> (Vec<u32>, Vec<u8>) {
    let mut rng = Rng::new(seed);
    let mut ids: Vec<u32> =
        rng.sample_distinct(universe as u64, n).into_iter().map(|v| v as u32).collect();
    ids.sort_unstable();
    let codec = CodecSpec::parse(codec_name).unwrap().id_codec().unwrap();
    let enc = codec.encode(&ids, universe);
    (ids, enc.bytes)
}

/// Run one decode attempt under catch_unwind on a watchdog thread.
/// Passes iff the decode returns: `Err` with `out` untouched, or `Ok`
/// with exactly `n` in-universe ids. Panics and hangs fail the case.
fn check_decode(codec_name: &'static str, bytes: Vec<u8>, universe: u32, n: usize, desc: String) {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let verdict = catch_unwind(AssertUnwindSafe(|| {
            let codec = CodecSpec::parse(codec_name).unwrap().id_codec().unwrap();
            let mut out = Vec::new();
            let mut scratch = DecodeScratch::default();
            match codec.try_decode_into(&bytes, universe, n, &mut out, &mut scratch) {
                Ok(()) => {
                    assert_eq!(out.len(), n, "Ok but wrong output length");
                    assert!(out.iter().all(|&v| v < universe), "Ok but out-of-universe id");
                }
                Err(_) => {
                    assert!(out.is_empty(), "Err but output not restored");
                }
            }
        }));
        let _ = tx.send(verdict.is_ok());
    });
    match rx.recv_timeout(TIME_GUARD) {
        Ok(true) => {}
        Ok(false) => panic!("{codec_name}: {desc}: decode panicked or broke its contract"),
        Err(_) => panic!("{codec_name}: {desc}: decode exceeded the {TIME_GUARD:?} guard"),
    }
}

#[test]
fn bit_flips_and_truncations_never_panic_or_hang() {
    let (universe, n) = (500u32, 80usize);
    for &codec in &PER_LIST_CODECS {
        let (_, bytes) = encoded_list(codec, universe, n, 0xC0FFEE);
        let mut rng = Rng::new(0xF00D);
        for case in 0..40 {
            let mut mutant = bytes.clone();
            if mutant.is_empty() {
                break;
            }
            let pos = rng.below(mutant.len() as u64) as usize;
            let mask = 1u8 << rng.below(8);
            mutant[pos] ^= mask;
            check_decode(codec, mutant, universe, n, format!("flip #{case} at byte {pos}"));
        }
        for case in 0..20 {
            let cut = rng.below(bytes.len() as u64 + 1) as usize;
            let mutant = bytes[..cut].to_vec();
            check_decode(codec, mutant, universe, n, format!("truncation #{case} to {cut}"));
        }
    }
}

#[test]
fn length_field_lies_are_rejected_or_safe() {
    let (universe, n) = (300u32, 50usize);
    for &codec in &PER_LIST_CODECS {
        let (_, bytes) = encoded_list(codec, universe, n, 0xBEEF);
        // Lie about the list length in both directions, including a
        // count the universe cannot even hold.
        for lie_n in [0usize, 1, n - 1, n + 1, 2 * n + 3, universe as usize + 5] {
            check_decode(
                codec,
                bytes.clone(),
                universe,
                lie_n,
                format!("declared n={lie_n} for a stream of {n}"),
            );
        }
        // Lie about the universe: shrink it below the ids actually
        // stored, and grow it past them.
        for lie_u in [1u32, universe / 2, universe - 1, universe + 1, u32::MAX] {
            check_decode(
                codec,
                bytes.clone(),
                lie_u,
                n,
                format!("declared universe={lie_u} for streams over {universe}"),
            );
        }
    }
}

#[test]
fn garbage_blobs_never_panic_or_hang() {
    let universe = 1000u32;
    for &codec in &PER_LIST_CODECS {
        let mut rng = Rng::new(0xDEAD_2BAD);
        for case in 0..30 {
            let len = rng.below(257) as usize;
            let blob: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            let n = rng.below(64) as usize;
            check_decode(codec, blob, universe, n, format!("garbage #{case} ({len} bytes, n={n})"));
        }
        // The canonical degenerate shapes.
        check_decode(codec, Vec::new(), universe, 0, "empty blob, n=0".into());
        check_decode(codec, Vec::new(), universe, 5, "empty blob, n=5".into());
        check_decode(codec, vec![0u8; 1024], 8, 9, "n exceeds universe".into());
    }
}
