//! Observability acceptance: the exposition layer's contracts exercised
//! through the public API, and the pipeline-stage tracer driven by a
//! real coordinator serving real queries.
//!
//! (Integration test on purpose: the tracer's sampling knob and ring
//! buffer are process-globals. The lib tests exercise their lifecycle in
//! one combined test; this binary is the only place that turns sampling
//! on while a coordinator is live, so the two can never interleave.)

use std::sync::Arc;
use zann::api::QueryParams;
use zann::coordinator::{Coordinator, ServeConfig};
use zann::datasets::{generate, Kind};
use zann::index::{IvfBuildParams, IvfIndex};
use zann::obs::expo::check_json_shape;
use zann::obs::trace;

/// Serve a batch with sampling at 1/1 and require every reply to leave a
/// complete stage timeline behind: spans recorded, each span's stage sum
/// equal to its end-to-end total (the residual stage guarantees it), and
/// the JSON dump well-formed.
#[test]
fn serving_under_full_sampling_records_complete_stage_timelines() {
    let ds = generate(Kind::DeepLike, 2_000, 64, 16, 7);
    let idx = Arc::new(IvfIndex::build(
        &ds.data,
        ds.dim,
        &IvfBuildParams { k: 32, seed: 7, ..Default::default() },
    ));
    let coord = Coordinator::start(
        idx,
        None,
        ServeConfig {
            batch_size: 16,
            search: QueryParams { k: 5, nprobe: 4, ..Default::default() },
            ..Default::default()
        },
    );
    trace::set_sample(1);
    let queries: Vec<Vec<f32>> = (0..ds.nq).map(|qi| ds.query(qi).to_vec()).collect();
    let responses = coord.client.search_many(queries).unwrap();
    trace::set_sample(0);
    coord.stop();
    assert_eq!(responses.len(), 64);
    let spans = trace::take_spans();
    if !zann::obs::enabled() {
        assert!(spans.is_empty(), "obs off: the tracer must never fire");
        return;
    }
    assert!(!spans.is_empty(), "sampling 1/1 over 64 queries must record spans");
    for t in &spans {
        assert!(t.total_ns > 0, "a served query takes nonzero time");
        // The residual stage absorbs whatever the explicit spans missed,
        // so the timeline always accounts for the full e2e latency
        // (the acceptance bound is ±10%; construction gives equality).
        assert_eq!(
            t.stage_sum_ns(),
            t.total_ns,
            "stage timeline must account for the end-to-end total"
        );
    }
    let json = trace::spans_json(&spans);
    check_json_shape(&json).expect("span dump must be well-formed JSON");
    assert!(json.contains("\"total_ns\""), "{json}");
    // Serving through the coordinator also feeds the aggregate stage
    // histograms used by the Prometheus view.
    let prom = zann::obs::global().render_prometheus();
    assert!(prom.contains("zann_stage_us"), "stage histograms must be exposed:\n{prom}");
}

/// Counter increments from many threads must all land in one series and
/// read back exactly from both renderings — the lock-free registry's
/// consistency contract at the exposition boundary.
#[test]
fn concurrent_writers_read_back_exactly_in_both_renderings() {
    if !zann::obs::enabled() {
        return;
    }
    let threads = 8;
    let per = 10_000u64;
    let hs: Vec<_> = (0..threads)
        .map(|_| {
            std::thread::spawn(move || {
                let c = zann::obs::counter("obs_expo_test_concurrent_total", &[]);
                for _ in 0..per {
                    c.inc();
                }
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
    let want = threads as u64 * per;
    let prom = zann::obs::global().render_prometheus();
    assert!(
        prom.contains(&format!("obs_expo_test_concurrent_total {want}")),
        "all {want} increments must be visible:\n{prom}"
    );
    let json = zann::obs::global().render_json();
    check_json_shape(&json).expect("render_json must be well-formed");
    assert!(json.contains(&format!("\"value\": {want}")), "{json}");
}

/// Histogram bucket boundaries as seen through the exposition: a value
/// of 100 lands in the `le="127"` bucket, and the cumulative counts are
/// monotone up to the explicit `+Inf`.
#[test]
fn histogram_buckets_expose_log2_boundaries() {
    if !zann::obs::enabled() {
        return;
    }
    let h = zann::obs::histogram("obs_expo_test_us", &[]);
    for v in [0u64, 1, 100, 1 << 20] {
        h.observe(v);
    }
    let prom = zann::obs::global().render_prometheus();
    let lines: Vec<&str> =
        prom.lines().filter(|l| l.starts_with("obs_expo_test_us_bucket")).collect();
    assert!(
        lines.iter().any(|l| l.contains("le=\"127\"")),
        "100 must occupy the le=127 bucket:\n{prom}"
    );
    assert!(
        lines.last().unwrap().contains("le=\"+Inf\"") && lines.last().unwrap().ends_with(" 4"),
        "+Inf must close the series at the total count:\n{prom}"
    );
    let mut last = 0u64;
    for l in &lines {
        let v: u64 = l.rsplit(' ').next().unwrap().parse().unwrap();
        assert!(v >= last, "cumulative buckets must be monotone:\n{prom}");
        last = v;
    }
    assert!(prom.contains("obs_expo_test_us_count 4"), "{prom}");
}

/// Label values holding quotes, backslashes, and newlines must be
/// escaped in the text format and survive the JSON rendering.
#[test]
fn hostile_label_values_are_escaped_in_both_renderings() {
    if !zann::obs::enabled() {
        return;
    }
    let c = zann::obs::counter("obs_expo_test_escaping_total", &[("tenant", "a\"b\\c\nd")]);
    c.inc();
    let prom = zann::obs::global().render_prometheus();
    assert!(
        prom.contains(r#"tenant="a\"b\\c\nd""#),
        "text format must escape quote/backslash/newline:\n{prom}"
    );
    let json = zann::obs::global().render_json();
    check_json_shape(&json).expect("hostile labels must not break the JSON rendering");
}

/// With the feature compiled out, the whole subsystem must vanish: no
/// sampling, no spans, no series — and the helpers still hand back
/// functional (orphan) handles so call sites need no cfg.
#[cfg(not(feature = "obs"))]
#[test]
fn obs_off_is_inert_but_callable() {
    assert!(!zann::obs::enabled());
    trace::set_sample(1);
    assert!(!trace::begin_query(), "sampling must never activate");
    trace::set_sample(0);
    let c = zann::obs::counter("obs_off_test_total", &[]);
    c.inc();
    assert_eq!(c.get(), 1, "orphan handles still count locally");
    let prom = zann::obs::global().render_prometheus();
    assert!(!prom.contains("obs_off_test_total"), "nothing registers when obs is off");
}
