//! The two pillars of the vectorized decode-and-scan engine, pinned as
//! properties:
//!
//! 1. **SIMD kernel parity** — every dispatch level the host supports
//!    must be *bit-identical* to the scalar reference on random inputs:
//!    the fused coarse kernel, the blocked ADC scan, and the batched
//!    tombstone filter. (ci.sh additionally runs the build→save→serve
//!    smoke under `ZANN_SIMD=scalar` and under the default dispatch and
//!    byte-compares the result dumps end-to-end.)
//! 2. **Interleaved ANS cross-decode** — `ans-i2`/`ans-i4`/`ans-i8`
//!    round-trip every list shape (0 / 1 / odd / power-of-two / large)
//!    and decode to *exactly* the same id sequence as their single-
//!    stream counterpart (one-way interleaving, whose encoder is pinned
//!    bit-identical to `Ans::encode_uniform` in the unit suite), across
//!    every per-list codec's set semantics.

use zann::ans::interleaved;
use zann::codecs::{CodecSpec, DecodeScratch, PER_LIST_CODECS};
use zann::datasets::{generate, Kind};
use zann::index::{IvfBuildParams, IvfIndex, SearchParams, SearchScratch, VectorMode};
use zann::quant::coarse;
use zann::simd;
use zann::util::Rng;

/// Dispatch levels this host can execute, weakest first.
fn supported_levels() -> Vec<simd::Level> {
    simd::Level::ALL.into_iter().filter(|&l| l <= simd::detected()).collect()
}

#[test]
fn coarse_kernel_levels_bit_identical_on_random_shapes() {
    let mut rng = Rng::new(0x51ead);
    for trial in 0..40 {
        let dim = 1 + rng.below(70) as usize;
        let k = rng.below(200) as usize;
        let q: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
        let cents: Vec<f32> = (0..k * dim).map(|_| rng.normal()).collect();
        let norms = coarse::centroid_norms(&cents, dim);
        let mut want = vec![0f32; k];
        simd::coarse::dists_into_level(simd::Level::Scalar, &q, &cents, dim, &norms, &mut want);
        // The scalar reference function itself is the level-0 path.
        let mut reference = vec![0f32; k];
        coarse::dists_into_scalar(&q, &cents, dim, &norms, &mut reference);
        assert_eq!(
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            reference.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "trial {trial}: Level::Scalar must be the scalar reference"
        );
        for level in supported_levels() {
            let mut got = vec![0f32; k];
            simd::coarse::dists_into_level(level, &q, &cents, dim, &norms, &mut got);
            for c in 0..k {
                assert_eq!(
                    got[c].to_bits(),
                    want[c].to_bits(),
                    "{}: trial {trial} dim={dim} k={k} c={c}",
                    level.name()
                );
            }
        }
    }
}

#[test]
fn adc_scan_levels_bit_identical_on_random_shapes() {
    let mut rng = Rng::new(0x51eae);
    for trial in 0..30 {
        let m = 1 + rng.below(12) as usize;
        let ksub = [16usize, 256, 1024][trial % 3];
        let n = rng.below(300) as usize;
        let lut: Vec<f32> = (0..m * ksub).map(|_| rng.normal()).collect();
        let codes: Vec<u16> = (0..n * m).map(|_| rng.below(ksub as u64) as u16).collect();
        let mut want = vec![0f32; n];
        simd::adc::adc_scan_level(simd::Level::Scalar, &lut, ksub, m, &codes, &mut want);
        for level in supported_levels() {
            let mut got = vec![0f32; n];
            simd::adc::adc_scan_level(level, &lut, ksub, m, &codes, &mut got);
            for r in 0..n {
                assert_eq!(
                    got[r].to_bits(),
                    want[r].to_bits(),
                    "{}: trial {trial} m={m} ksub={ksub} row {r}",
                    level.name()
                );
            }
        }
    }
}

#[test]
fn tombstone_filter_levels_agree_on_random_bitmaps() {
    let mut rng = Rng::new(0x51eaf);
    for trial in 0..30 {
        let universe = 1 + rng.below(10_000) as u32;
        let mut words = vec![0u64; (universe as usize).div_ceil(64)];
        for _ in 0..rng.below(universe as u64 / 2 + 1) {
            let id = rng.below(universe as u64) as usize;
            words[id / 64] |= 1 << (id % 64);
        }
        let n = rng.below(500) as usize;
        let exts: Vec<u32> = (0..n)
            .map(|i| {
                if i % 7 == 0 {
                    universe.saturating_add(rng.below(1000) as u32)
                } else {
                    rng.below(universe as u64) as u32
                }
            })
            .collect();
        let mut want = Vec::new();
        simd::filter::live_positions_level(simd::Level::Scalar, &words, &exts, &mut want);
        for level in supported_levels() {
            let mut got = Vec::new();
            simd::filter::live_positions_level(level, &words, &exts, &mut got);
            assert_eq!(got, want, "{}: trial {trial} n={n}", level.name());
        }
    }
}

#[test]
fn interleaved_roundtrip_and_cross_decode_against_single_stream() {
    // (a) of the property-test satellite: for every list shape — empty,
    // singleton, odd, power-of-two, larger-than-any-interleave, and the
    // full universe — each interleaved width round-trips the set and
    // decodes the exact sequence the single-stream (1-way) coder emits.
    let mut rng = Rng::new(0xc0de);
    for &universe in &[1u32, 2, 97, 4096, 1 << 20, u32::MAX] {
        for &n in &[0usize, 1, 3, 8, 17, 64, 257, 2000] {
            if n as u64 > universe as u64 {
                continue;
            }
            let ids: Vec<u32> =
                rng.sample_distinct(universe as u64, n).into_iter().map(|v| v as u32).collect();
            let mut sorted = ids.clone();
            sorted.sort_unstable();
            // Single-stream reference: 1-way interleaving.
            let mut single = Vec::new();
            interleaved::decode_uniform_into(
                &interleaved::encode_uniform(&sorted, universe.max(1), 1),
                universe.max(1),
                n,
                1,
                &mut single,
            );
            assert_eq!(single, sorted, "single-stream decode must be ascending");
            for name in ["ans-i2", "ans-i4", "ans-i8"] {
                let codec = CodecSpec::parse(name).unwrap().id_codec().unwrap();
                let enc = codec.encode(&ids, universe);
                let mut out = Vec::new();
                codec.decode(&enc.bytes, universe, n, &mut out);
                assert_eq!(out, single, "{name}: universe={universe} n={n} cross-decode");
                let mut scratched = Vec::new();
                codec.decode_into(
                    &enc.bytes,
                    universe,
                    n,
                    &mut scratched,
                    &mut DecodeScratch::default(),
                );
                assert_eq!(scratched, out, "{name}: decode_into parity");
            }
        }
    }
}

#[test]
fn every_per_list_codec_decodes_the_same_id_set() {
    // Set-level cross-codec agreement on one list (sorted views equal),
    // covering the whole registry including the interleaved family.
    let mut rng = Rng::new(0xc0df);
    let universe = 50_000u32;
    for &n in &[0usize, 1, 13, 777] {
        let ids: Vec<u32> =
            rng.sample_distinct(universe as u64, n).into_iter().map(|v| v as u32).collect();
        let mut want = ids.clone();
        want.sort_unstable();
        for name in PER_LIST_CODECS {
            let codec = CodecSpec::parse(name).unwrap().id_codec().unwrap();
            let enc = codec.encode(&ids, universe);
            let mut out = Vec::new();
            codec.decode(&enc.bytes, universe, n, &mut out);
            out.sort_unstable();
            assert_eq!(out, want, "{name}: n={n}");
        }
    }
}

#[test]
fn ivf_search_results_identical_across_ans_widths_and_stores() {
    // End-to-end: the interleaved codecs are lossless, so search results
    // must equal the unc64 baseline's exactly — including through the
    // blocked SIMD ADC scan of the PQ store.
    let ds = generate(Kind::DeepLike, 3000, 25, 16, 0xbeef);
    let sp = SearchParams { nprobe: 8, k: 10 };
    for vectors in [VectorMode::Flat, VectorMode::Pq { m: 4, bits: 8 }] {
        let mut baseline: Option<Vec<Vec<(f32, u32)>>> = None;
        for codec in ["unc64", "ans-i2", "ans-i4", "ans-i8"] {
            let idx = IvfIndex::build(
                &ds.data,
                ds.dim,
                &IvfBuildParams {
                    k: 32,
                    id_codec: codec.into(),
                    vectors: vectors.clone(),
                    threads: 2,
                    ..Default::default()
                },
            );
            let mut scratch = SearchScratch::default();
            let res: Vec<Vec<(f32, u32)>> =
                (0..ds.nq).map(|qi| idx.search(ds.query(qi), &sp, &mut scratch)).collect();
            match &baseline {
                None => baseline = Some(res),
                Some(b) => assert_eq!(&res, b, "codec={codec} vectors={vectors:?}"),
            }
        }
    }
}
