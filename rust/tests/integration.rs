//! Integration tests across modules: the PJRT AOT round-trip (python HLO →
//! rust execute), the full serving stack, and cross-codec index identity.
//!
//! PJRT tests require `make artifacts` to have run (the Makefile `test`
//! target guarantees it); they skip gracefully if artifacts are missing so
//! `cargo test` works in a fresh checkout too.

use std::sync::Arc;
use zann::api::QueryParams;
use zann::coordinator::{Coordinator, ServeConfig};
use zann::datasets::{generate, Kind};
use zann::index::{IvfBuildParams, IvfIndex, SearchParams, SearchScratch};
use zann::runtime::{coarse_fallback, Engine, EngineHandle};
use zann::util::Rng;

fn artifact_dir() -> std::path::PathBuf {
    let mut p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    p.push("artifacts");
    p
}

fn have_artifacts() -> bool {
    artifact_dir().join("coarse__b64_k1024_d32.hlo.txt").exists()
}

#[test]
fn pjrt_coarse_matches_rust_fallback() {
    if !have_artifacts() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let engine = Engine::load(&artifact_dir()).expect("engine load");
    assert!(engine.num_executables() >= 5, "expected the full artifact grid");
    let mut rng = Rng::new(1);
    for &(b, k, d) in &[(64usize, 1024usize, 32usize), (64, 256, 32), (64, 2048, 32), (1, 1024, 32)]
    {
        assert!(engine.has_coarse((b, k, d)), "missing artifact b{b}_k{k}_d{d}");
        let q: Vec<f32> = (0..b * d).map(|_| rng.normal()).collect();
        let c: Vec<f32> = (0..k * d).map(|_| rng.normal()).collect();
        let (got, via_pjrt) = engine.coarse(&q, b, d, &c, k).unwrap();
        assert!(via_pjrt);
        let want = coarse_fallback(&q, b, d, &c, k);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < 1e-2 * w.abs().max(1.0),
                "b{b}k{k}d{d} elem {i}: pjrt={g} rust={w}"
            );
        }
    }
}

#[test]
fn pjrt_unknown_shape_falls_back() {
    if !have_artifacts() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let engine = Engine::load(&artifact_dir()).expect("engine load");
    let (out, via_pjrt) = engine.coarse(&[0.0; 3 * 7], 3, 7, &[0.0; 5 * 7], 5).unwrap();
    assert!(!via_pjrt);
    assert_eq!(out.len(), 15);
}

#[test]
fn serving_through_pjrt_engine_end_to_end() {
    if !have_artifacts() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    // dim/k match the shipped artifact grid: (b=64, k=1024, d=32).
    let ds = generate(Kind::DeepLike, 30_000, 256, 32, 23);
    let idx = Arc::new(IvfIndex::build(
        &ds.data,
        32,
        &IvfBuildParams { k: 1024, id_codec: "roc".into(), ..Default::default() },
    ));
    let engine = EngineHandle::spawn(&artifact_dir()).expect("engine spawn");
    let coord = Coordinator::start(
        idx.clone(),
        Some(engine),
        ServeConfig {
            batch_size: 64,
            search: QueryParams { nprobe: 16, k: 10, ..Default::default() },
            ..Default::default()
        },
    );
    let queries: Vec<Vec<f32>> = (0..256).map(|qi| ds.query(qi).to_vec()).collect();
    let responses = coord.client.search_many(queries).unwrap();
    // At least some batches were full (64) and went through PJRT.
    assert!(responses.iter().any(|r| r.via_pjrt), "no batch hit the PJRT path");
    // Results identical to the pure-rust direct search.
    let sp = SearchParams { nprobe: 16, k: 10 };
    let mut scratch = SearchScratch::default();
    for (qi, resp) in responses.iter().enumerate() {
        let want = idx.search(ds.query(qi), &sp, &mut scratch);
        let got_ids: Vec<u32> = resp.results.iter().map(|r| r.1).collect();
        let want_ids: Vec<u32> = want.iter().map(|r| r.1).collect();
        assert_eq!(got_ids, want_ids, "query {qi} differs between PJRT and rust coarse");
    }
    coord.stop();
}

#[test]
fn ivf_and_nsg_agree_on_easy_queries() {
    // Cross-index sanity: both index families find a *planted* neighbor
    // (query = database point + tiny noise).
    let ds = generate(Kind::DeepLike, 5_000, 1, 16, 24);
    let mut rng = Rng::new(99);
    let mut queries = Vec::new();
    let mut planted = Vec::new();
    for q in 0..30usize {
        let target = (q * 131) % ds.n;
        planted.push(target as u32);
        for d in 0..16 {
            queries.push(ds.data[target * 16 + d] + 1e-4 * rng.normal());
        }
    }
    let ivf = IvfIndex::build(
        &ds.data,
        16,
        &IvfBuildParams { k: 64, id_codec: "ef".into(), ..Default::default() },
    );
    let nsg = zann::graph::nsg::Nsg::build(
        &ds.data,
        16,
        &zann::graph::nsg::NsgParams { r: 24, knn_k: 32, ..Default::default() },
    );
    let sp = SearchParams { nprobe: 16, k: 1 };
    let mut scratch = SearchScratch::default();
    let (mut ivf_hits, mut nsg_hits) = (0, 0);
    for (q, &target) in planted.iter().enumerate() {
        let query = &queries[q * 16..(q + 1) * 16];
        if ivf.search(query, &sp, &mut scratch).first().map(|r| r.1) == Some(target) {
            ivf_hits += 1;
        }
        if nsg.search(&ds.data, query, 128, 1).first().map(|r| r.1) == Some(target) {
            nsg_hits += 1;
        }
    }
    assert!(ivf_hits >= 27, "ivf found {ivf_hits}/30 planted neighbors");
    assert!(nsg_hits >= 24, "nsg found {nsg_hits}/30 planted neighbors");
}

#[test]
fn offline_blob_roundtrip_via_all_graph_coders() {
    use zann::codecs::rec::{Rec, RecModel};
    use zann::codecs::zuckerli::Zuckerli;
    let ds = generate(Kind::DeepLike, 2_000, 1, 12, 25);
    let h = zann::graph::hnsw::Hnsw::build(
        &ds.data,
        12,
        &zann::graph::hnsw::HnswParams { m: 12, ef_construction: 60, seed: 1 },
    );
    let adj = h.base_adj();
    let e: u64 = adj.iter().map(|l| l.len() as u64).sum();
    let norm = |a: &[Vec<u32>]| -> Vec<Vec<u32>> {
        a.iter()
            .map(|l| {
                let mut l = l.clone();
                l.sort_unstable();
                l
            })
            .collect()
    };
    for model in [RecModel::Uniform, RecModel::PolyaUrn] {
        let rec = Rec::new(model);
        let enc = rec.encode_graph(adj);
        assert_eq!(norm(&rec.decode_graph(&enc.bytes, 2_000, e)), norm(adj));
    }
    let z = Zuckerli::default();
    assert_eq!(z.decode_graph(&z.encode_graph(adj).bytes, 2_000), norm(adj));
}
