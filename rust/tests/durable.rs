//! Durability acceptance: the WAL + atomic-commit contract end to end.
//!
//! - Torn-tail truncation at **every byte offset** of the WAL's last
//!   record recovers exactly the acknowledged prefix — no more, no less —
//!   and discloses the torn bytes.
//! - Replay-then-search is bit-identical to the uncrashed store across
//!   all per-list id codecs (the same invariant `inject-crashes` gates at
//!   larger scale in CI).
//! - An injected crash at every point of the atomic container commit
//!   leaves the destination opening as a complete old or new index,
//!   never a torn one.
//! - Checkpoints roll the manifest generation, reset the WAL, and drop
//!   the superseded generation's files.

use std::path::{Path, PathBuf};

use zann::api::{persist, AnnIndex, AnnScratch, QueryParams};
use zann::datasets::{generate, Kind};
use zann::durable::store::{apply, DurableDynamic};
use zann::durable::{crash, wal};
use zann::dynamic::{CompactionPolicy, DynamicBuildParams, DynamicIvf};
use zann::index::{IvfBuildParams, IvfIndex};

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("zann-durable-test-{}-{name}", std::process::id()))
}

fn sig(idx: &dyn AnnIndex, queries: &[f32], dim: usize) -> Vec<(u32, u32)> {
    let p = QueryParams { k: 5, nprobe: 4, ef: 16 };
    let mut scratch = AnnScratch::default();
    let mut out = Vec::new();
    let mut sig = Vec::new();
    for q in queries.chunks_exact(dim) {
        idx.search_into(q, &p, &mut scratch, &mut out);
        sig.extend(out.iter().map(|&(d, id)| (d.to_bits(), id)));
    }
    sig
}

fn build_dynamic(data: &[f32], dim: usize, codec: &str) -> DynamicIvf {
    DynamicIvf::build(
        data,
        dim,
        &DynamicBuildParams {
            ivf: IvfBuildParams { k: 4, id_codec: codec.into(), threads: 2, ..Default::default() },
            policy: CompactionPolicy { flush_rows: 32, auto: false, ..Default::default() },
        },
    )
    .unwrap()
}

fn copy_dir(src: &Path, dst: &Path) {
    let _ = std::fs::remove_dir_all(dst);
    std::fs::create_dir_all(dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap() {
        let p = entry.unwrap().path();
        if p.is_file() {
            std::fs::copy(&p, dst.join(p.file_name().unwrap())).unwrap();
        }
    }
}

#[test]
fn torn_tail_truncation_recovers_exactly_the_acked_prefix() {
    let ds = generate(Kind::DeepLike, 140, 6, 8, 11);
    let dim = ds.dim;
    let base = build_dynamic(&ds.data[..120 * dim], dim, "roc");
    let root = tmp("torn-tail");
    let _ = std::fs::remove_dir_all(&root);
    let template = root.join("template");
    let mut store = DurableDynamic::create(&template, base.clone()).unwrap();
    store.add(&ds.data[120 * dim..130 * dim]).unwrap();
    assert!(store.delete(7).unwrap());
    store.add(&ds.data[130 * dim..]).unwrap();
    drop(store);

    let wal_path = template.join("wal-0.log");
    let wal_bytes = std::fs::read(&wal_path).unwrap();
    let replay = wal::replay(&wal_path).unwrap();
    assert_eq!(replay.records.len(), 3);
    assert_eq!(replay.torn_bytes, 0);

    // Reference signatures with 0..=3 records applied.
    let mut ref_sigs = Vec::new();
    let mut reference = base;
    ref_sigs.push(sig(&reference, &ds.queries, dim));
    for rec in &replay.records {
        apply(&mut reference, rec).unwrap();
        ref_sigs.push(sig(&reference, &ds.queries, dim));
    }

    // Frame boundaries of the intact log.
    let mut boundaries = vec![wal::WAL_HEADER as usize];
    let mut pos = wal::WAL_HEADER as usize;
    while pos < wal_bytes.len() {
        let len = u32::from_le_bytes(wal_bytes[pos..pos + 4].try_into().unwrap()) as usize;
        pos += 8 + len;
        boundaries.push(pos);
    }
    assert_eq!(pos, wal_bytes.len());
    assert_eq!(boundaries.len(), 4);

    // Truncate at every byte offset of the last record (from its first
    // byte through the intact file). Each cut must recover exactly the
    // records whose frames survived whole.
    let work = root.join("work");
    for cut in boundaries[2]..=wal_bytes.len() {
        copy_dir(&template, &work);
        std::fs::write(work.join("wal-0.log"), &wal_bytes[..cut]).unwrap();
        let (store, stats) = DurableDynamic::open(&work).unwrap();
        let acked = boundaries.iter().filter(|&&b| b <= cut).count() - 1;
        assert_eq!(stats.replayed_records, acked, "cut at byte {cut}");
        assert_eq!(stats.torn_bytes as usize, cut - boundaries[acked], "cut at byte {cut}");
        assert_eq!(
            sig(store.index(), &ds.queries, dim),
            ref_sigs[acked],
            "recovered state diverged at cut {cut}"
        );
        drop(store);
    }

    // After a torn-tail recovery the log accepts appends again.
    copy_dir(&template, &work);
    std::fs::write(work.join("wal-0.log"), &wal_bytes[..wal_bytes.len() - 1]).unwrap();
    let (mut store, stats) = DurableDynamic::open(&work).unwrap();
    assert_eq!(stats.replayed_records, 2);
    assert!(stats.torn_bytes > 0);
    store.add(&ds.data[..dim]).unwrap();
    drop(store);
    let (_, stats) = DurableDynamic::open(&work).unwrap();
    assert_eq!(stats.replayed_records, 3);
    assert_eq!(stats.torn_bytes, 0);

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn replay_then_search_is_bit_identical_across_all_codecs() {
    let ds = generate(Kind::DeepLike, 160, 6, 8, 17);
    let dim = ds.dim;
    for codec in zann::codecs::PER_LIST_CODECS {
        let root = tmp(&format!("codec-{codec}"));
        let _ = std::fs::remove_dir_all(&root);
        let base = build_dynamic(&ds.data[..120 * dim], dim, codec);
        let mut store = DurableDynamic::create(&root, base).unwrap();
        store.add(&ds.data[120 * dim..150 * dim]).unwrap();
        for id in [3u32, 60, 125] {
            assert!(store.delete(id).unwrap(), "{codec}: delete {id}");
        }
        store.add(&ds.data[150 * dim..]).unwrap();
        let live_sig = sig(store.index(), &ds.queries, dim);
        drop(store);

        let (store, stats) = DurableDynamic::open(&root).unwrap();
        assert_eq!(stats.replayed_records, 5, "{codec}");
        assert_eq!(stats.torn_bytes, 0, "{codec}");
        assert!(stats.replayed_rows == 40 && stats.replayed_deletes == 3, "{codec}");
        assert_eq!(
            sig(store.index(), &ds.queries, dim),
            live_sig,
            "replay diverged from the uncrashed store for codec {codec}"
        );
        drop(store);
        let _ = std::fs::remove_dir_all(&root);
    }
}

#[test]
fn injected_commit_crashes_never_tear_a_saved_container() {
    let ds = generate(Kind::DeepLike, 200, 4, 8, 23);
    let dim = ds.dim;
    let old = IvfIndex::build(
        &ds.data[..150 * dim],
        dim,
        &IvfBuildParams { k: 4, id_codec: "roc".into(), threads: 2, ..Default::default() },
    );
    let new = IvfIndex::build(
        &ds.data,
        dim,
        &IvfBuildParams { k: 6, id_codec: "roc".into(), threads: 2, ..Default::default() },
    );
    let root = tmp("atomic-save");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let path = root.join("index.zann");
    persist::save(&old, &path).unwrap();
    let old_n = persist::open(&path).unwrap().stats().n;

    let mut fired_any = false;
    for nth in 0..64u64 {
        crash::arm(nth);
        let res = persist::save(&new, &path);
        match crash::disarm() {
            None => {
                res.unwrap();
                break;
            }
            Some(site) => {
                fired_any = true;
                assert!(res.is_err(), "save returned Ok though a crash fired at {site}");
                let got = persist::open(&path).unwrap_or_else(|e| {
                    panic!("container torn after injected crash at {site}: {e:?}")
                });
                let n = got.stats().n;
                assert!(
                    n == old_n || n == new.stats().n,
                    "crash at {site} left a mixed container (n={n})"
                );
            }
        }
    }
    assert!(fired_any, "no crash point was ever reached by persist::save");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn checkpoint_rolls_the_generation_and_resets_the_wal() {
    let ds = generate(Kind::DeepLike, 140, 4, 8, 29);
    let dim = ds.dim;
    let root = tmp("ckpt");
    let _ = std::fs::remove_dir_all(&root);
    let mut store =
        DurableDynamic::create(&root, build_dynamic(&ds.data[..120 * dim], dim, "roc")).unwrap();
    store.add(&ds.data[120 * dim..]).unwrap();
    assert!(store.delete(5).unwrap());
    assert!(store.wal_bytes() > wal::WAL_HEADER);
    let live_sig = sig(store.index(), &ds.queries, dim);

    store.checkpoint().unwrap();
    assert_eq!(store.generation(), 1);
    assert_eq!(store.wal_bytes(), wal::WAL_HEADER);
    assert!(root.join("base-1.zann").exists());
    assert!(root.join("wal-1.log").exists());
    assert!(!root.join("base-0.zann").exists(), "old generation not cleaned up");
    assert!(!root.join("wal-0.log").exists(), "old wal not cleaned up");
    // Compaction + generation roll never changes answers.
    assert_eq!(sig(store.index(), &ds.queries, dim), live_sig);
    drop(store);

    let (store, stats) = DurableDynamic::open(&root).unwrap();
    assert_eq!(stats.generation, 1);
    assert_eq!(stats.replayed_records, 0);
    assert_eq!(stats.torn_bytes, 0);
    assert_eq!(sig(store.index(), &ds.queries, dim), live_sig);
    drop(store);
    let _ = std::fs::remove_dir_all(&root);
}
