//! Index migration: take an existing uncompressed IVF-PQ deployment and
//! re-encode its id payload (and optionally its PQ codes) without
//! rebuilding the quantizers — the Table-4 "-30% of the index" scenario.
//!
//!     cargo run --release --example index_migration [-- --n 200000]

use zann::datasets::{generate, Kind};
use zann::index::{IvfBuildParams, IvfIndex, SearchParams, SearchScratch, VectorMode};
use zann::quant::kmeans;
use zann::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let n = args.usize("n", 200_000);
    let k = args.usize("k", 2048);
    let dim = 32;
    let ds = generate(Kind::DeepLike, n, 64, dim, 5);

    // The "existing deployment": one clustering, shared by every variant
    // (migration must not retrain the coarse quantizer).
    println!("training coarse quantizer (K={k}) once...");
    let cents = kmeans::train(
        &ds.data,
        dim,
        &kmeans::KmeansConfig { k, iters: 8, seed: 5, ..Default::default() },
    );
    let kk = cents.len() / dim;
    let assign = kmeans::assign(&ds.data, dim, &cents, zann::util::pool::default_threads());

    let build = |codec: &str, vectors: VectorMode| -> IvfIndex {
        IvfIndex::build_preassigned(
            &ds.data,
            dim,
            &cents,
            &assign,
            &IvfBuildParams { k: kk, id_codec: codec.into(), vectors, ..Default::default() },
            kk,
        )
    };

    let before = build("unc64", VectorMode::Pq { m: 8, bits: 8 });
    let after = build("roc", VectorMode::Pq { m: 8, bits: 8 });
    let after_full = build("roc", VectorMode::PqCompressed { m: 8, bits: 8 });

    let total = |idx: &IvfIndex| (idx.id_bits() + idx.code_bits()) as f64 / 8.0 / (1 << 20) as f64;
    println!("\n{:<26} {:>10} {:>10} {:>10}", "index", "ids MiB", "codes MiB", "total MiB");
    for (label, idx) in [
        ("unc64 + PQ8", &before),
        ("ROC ids + PQ8", &after),
        ("ROC ids + coded PQ8", &after_full),
    ] {
        println!(
            "{label:<26} {:>10.2} {:>10.2} {:>10.2}",
            idx.id_bits() as f64 / 8.0 / (1 << 20) as f64,
            idx.code_bits() as f64 / 8.0 / (1 << 20) as f64,
            total(idx)
        );
    }
    println!(
        "\nindex shrinks by {:.0}% (ids only) / {:.0}% (ids+codes), paper Table 4 reports -30%",
        100.0 * (1.0 - total(&after) / total(&before)),
        100.0 * (1.0 - total(&after_full) / total(&before)),
    );

    // Same result *distances* before and after migration. (Ids can differ
    // only where two vectors share identical PQ codes and therefore tie
    // exactly in ADC distance — the boundary order among exact ties is
    // arbitrary; the returned distance profile must be bit-identical.)
    let sp = SearchParams { nprobe: 16, k: 10 };
    let mut s = SearchScratch::default();
    for qi in 0..ds.nq {
        let a: Vec<f32> = before.search(ds.query(qi), &sp, &mut s).iter().map(|r| r.0).collect();
        let b: Vec<f32> = after.search(ds.query(qi), &sp, &mut s).iter().map(|r| r.0).collect();
        assert_eq!(a, b, "migration changed result distances at query {qi}");
    }
    println!("verified: identical result distances before/after migration");
}
