//! Offline graph-blob compression (paper §4.3 / Table 3): build an NSG
//! index, compress the whole graph with REC and the Zuckerli-style coder,
//! verify lossless round-trip, and report sizes.
//!
//!     cargo run --release --example offline_graph [-- --n 30000 --r 32]

use zann::codecs::rec::{Rec, RecModel};
use zann::codecs::zuckerli::Zuckerli;
use zann::datasets::{generate, Kind};
use zann::graph::nsg::{Nsg, NsgParams};
use zann::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let n = args.usize("n", 30_000);
    let r = args.usize("r", 32);
    println!("building NSG{r} over {n} sift-like vectors...");
    let ds = generate(Kind::SiftLike, n, 1, 32, 3);
    let nsg = Nsg::build(&ds.data, ds.dim, &NsgParams { r, knn_k: r.max(48), ..Default::default() });
    let e = nsg.num_edges();
    println!("graph: {n} nodes, {e} edges ({:.1} avg degree)", e as f64 / n as f64);

    let compact_bits = zann::util::bits_for(n as u64) as f64;
    println!("\n{:<12} {:>10} {:>12}", "coder", "bits/edge", "total MiB");
    println!("{:<12} {:>10.2} {:>12.2}", "unc32", 32.0, (e * 32) as f64 / 8.0 / (1 << 20) as f64);
    println!("{:<12} {:>10.2} {:>12.2}", "compact", compact_bits, e as f64 * compact_bits / 8.0 / (1 << 20) as f64);

    let z = Zuckerli::default().encode_graph(&nsg.adj);
    println!("{:<12} {:>10.2} {:>12.2}", "zuckerli", z.bits as f64 / e as f64, z.bits as f64 / 8.0 / (1 << 20) as f64);

    for (label, model) in [("rec(unif)", RecModel::Uniform), ("rec(urn)", RecModel::PolyaUrn)] {
        let rec = Rec::new(model);
        let enc = rec.encode_graph(&nsg.adj);
        println!(
            "{:<12} {:>10.2} {:>12.2}",
            label,
            enc.bits as f64 / e as f64,
            enc.bits as f64 / 8.0 / (1 << 20) as f64
        );
        // Verify lossless round-trip.
        let decoded = rec.decode_graph(&enc.bytes, n as u32, e);
        let norm = |adj: &[Vec<u32>]| -> Vec<Vec<u32>> {
            adj.iter()
                .map(|l| {
                    let mut l = l.clone();
                    l.sort_unstable();
                    l
                })
                .collect()
        };
        assert_eq!(norm(&decoded), norm(&nsg.adj), "{label} round-trip failed");
    }
    println!("\nround-trips verified: decompressed graphs are identical");
}
