//! End-to-end serving driver (the system-prop validation run): build an
//! IVF-PQ index over a realistic synthetic collection, bring up the full
//! three-layer stack — rust coordinator + PJRT engine executing the
//! AOT-compiled JAX/Pallas coarse kernel — and serve batched queries,
//! reporting latency percentiles, throughput and recall.
//!
//!     make artifacts && cargo run --release --example serving
//!
//! Flags: --n --nq --k --nprobe --codec --no-engine

use std::sync::Arc;
use zann::api::QueryParams;
use zann::coordinator::{Coordinator, ServeConfig};
use zann::datasets::{generate, groundtruth, Kind};
use zann::index::{IvfBuildParams, IvfIndex, VectorMode};
use zann::runtime::{default_artifact_dir, EngineHandle};
use zann::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let n = args.usize("n", 200_000);
    let nq = args.usize("nq", 4096);
    let k = args.usize("k", 1024);
    let dim = 32; // matches the shipped coarse__b64_k1024_d32 artifact
    let codec = args.get_or("codec", "roc");

    println!("[1/4] generating {} deep-like vectors (dim {dim})...", n);
    let ds = generate(Kind::DeepLike, n, nq, dim, 7);

    println!("[2/4] building IVF{k} + PQ16, ids via {codec}...");
    let idx = Arc::new(IvfIndex::build(
        &ds.data,
        dim,
        &IvfBuildParams {
            k,
            id_codec: codec.into(),
            vectors: VectorMode::Pq { m: 16, bits: 8 },
            ..Default::default()
        },
    ));
    println!(
        "      id payload {:.2} bits/id ({:.1}x vs 64-bit), codes {:.1} bits/vec",
        idx.bits_per_id(),
        64.0 / idx.bits_per_id(),
        idx.code_bits() as f64 / idx.n as f64
    );

    println!("[3/4] starting engine + coordinator...");
    let engine = if args.bool("no-engine") {
        None
    } else {
        match EngineHandle::spawn(&default_artifact_dir()) {
            Ok(h) => {
                println!("      PJRT engine: {} compiled executables", h.num_executables);
                Some(h)
            }
            Err(e) => {
                println!("      engine unavailable ({e}); falling back to rust coarse path");
                None
            }
        }
    };
    let coord = Coordinator::start(
        idx.clone(),
        engine,
        ServeConfig {
            batch_size: 64,
            search: QueryParams { nprobe: args.usize("nprobe", 32), k: 10, ..Default::default() },
            ..Default::default()
        },
    );

    println!("[4/4] serving {} queries...", nq);
    let queries: Vec<Vec<f32>> = (0..nq).map(|qi| ds.query(qi).to_vec()).collect();
    let t0 = std::time::Instant::now();
    let responses = coord.client.search_many(queries).unwrap();
    let wall = t0.elapsed().as_secs_f64();

    // Recall against exact ground truth on a subset.
    let sub = nq.min(500);
    let gt = groundtruth::exact_knn(&ds.data, &ds.queries[..sub * dim], dim, 10, 8);
    let results: Vec<Vec<u32>> = responses[..sub]
        .iter()
        .map(|r| r.results.iter().map(|&(_, id)| id).collect())
        .collect();
    let recall = groundtruth::nn_recall_at_k(&gt, 10, &results, 10);

    println!("---------------------------------------------");
    println!("throughput: {:.0} queries/s ({} queries in {:.3}s)", nq as f64 / wall, nq, wall);
    println!("metrics:    {}", coord.metrics.summary());
    println!("recall@10:  {recall:.3} (IVF-PQ, nprobe={})", args.usize("nprobe", 32));
    let pjrt = responses.iter().filter(|r| r.via_pjrt).count();
    println!("pjrt path:  {pjrt}/{} responses", responses.len());
    coord.stop();
}
