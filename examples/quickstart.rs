//! Quickstart: build an IVF index with ROC-compressed ids, search it, and
//! compare the id payload against the uncompressed baseline.
//!
//!     cargo run --release --example quickstart

use zann::datasets::{generate, groundtruth, Kind};
use zann::index::{IvfBuildParams, IvfIndex, SearchParams, SearchScratch};

fn main() {
    // 1. A synthetic "Deep1M-like" collection (50k vectors, 32-d).
    let ds = generate(Kind::DeepLike, 50_000, 100, 32, 0xbeef);
    println!("dataset: {} vectors, {} queries, dim {}", ds.n, ds.nq, ds.dim);

    // 2. Build two IVF1024 indexes that differ only in id storage.
    let mut params = IvfBuildParams { k: 1024, id_codec: "unc64".into(), ..Default::default() };
    let unc = IvfIndex::build(&ds.data, ds.dim, &params);
    params.id_codec = "roc".into();
    let roc = IvfIndex::build(&ds.data, ds.dim, &params);
    println!(
        "id payload: unc64 {:.1} bits/id  |  ROC {:.2} bits/id  ({:.1}x smaller)",
        unc.bits_per_id(),
        roc.bits_per_id(),
        unc.bits_per_id() / roc.bits_per_id()
    );

    // 3. Search both: identical results (compression is lossless).
    let sp = SearchParams { nprobe: 16, k: 10 };
    let mut scratch = SearchScratch::default();
    let gt = groundtruth::exact_knn(&ds.data, &ds.queries, ds.dim, 10, 8);
    let mut same = true;
    let mut results = Vec::new();
    for qi in 0..ds.nq {
        let a = unc.search(ds.query(qi), &sp, &mut scratch);
        let b = roc.search(ds.query(qi), &sp, &mut scratch);
        same &= a.iter().map(|r| r.1).eq(b.iter().map(|r| r.1));
        results.push(b.into_iter().map(|(_, id)| id).collect::<Vec<_>>());
    }
    let recall = groundtruth::nn_recall_at_k(&gt, 10, &results, 10);
    println!("identical results across codecs: {same}");
    println!("recall@10 = {recall:.3} (nprobe=16)");
    assert!(same, "lossless id compression must not change results");
}
